"""Deterministic virtual clock for the threaded cloud-edge runtime.

The runtime (``Channel``, ``CloudVerifier``, ``EdgeClient``) never calls
``time.monotonic``/``time.sleep``/``threading.Condition`` directly — every
timing primitive goes through a *clock* object so the same code runs in two
modes:

* ``SystemClock`` — thin delegation to ``time``/``threading``; production and
  wall-clock benchmarks behave exactly as before;
* ``VirtualClock`` — a discrete-event scheduler.  Code running under it is
  organised into *actors* (cooperatively scheduled real threads).  At most
  one actor executes at a time; an actor only yields control at a clock
  primitive (``sleep``, ``Condition.wait``, ``join``), and the clock advances
  virtual time **only when every actor is blocked**, jumping straight to the
  earliest wake deadline.  Actor wake order is a deterministic function of
  (wake time, registration order), so a whole multi-session serving run —
  dispatcher, rx loops, edge clients, fault injection — is bit-reproducible
  from its seeds with zero wall-clock dependence: simulated hours run in
  host milliseconds and two runs produce identical token streams and stats.

Usage::

    clock = VirtualClock()
    ch = Channel(cfg, clock=clock)
    server = CloudVerifier(backend, clock=clock)

    def scenario():
        server.start()
        stats = client.run(64)
        server.stop()
        return stats

    stats = clock.run(scenario)   # drives the event loop to completion

Blocking primitives (``sleep``/``wait``/``join``) may only be called from
inside ``clock.run`` / ``clock.spawn`` actors; non-blocking ones
(``monotonic``, ``notify_all``, ``send``) work anywhere, so test setup can
pre-load channels before the event loop starts.
"""

from __future__ import annotations

import threading
import time
from typing import Any, Callable, Dict, List, Optional

__all__ = ["SystemClock", "VirtualClock", "ActorHandle", "SYSTEM_CLOCK"]


class SystemClock:
    """Wall-clock implementation of the clock surface (the default)."""

    virtual = False

    def monotonic(self) -> float:
        """Wall ``time.monotonic()``."""
        return time.monotonic()

    def sleep(self, dt: float) -> None:
        """Wall ``time.sleep`` (clamped at 0)."""
        time.sleep(max(dt, 0.0))

    def condition(self, lock: Optional[threading.Lock] = None) -> threading.Condition:
        """A real ``threading.Condition`` (optionally over an existing lock)."""
        return threading.Condition(lock) if lock is not None else threading.Condition()

    def spawn(self, fn: Callable[[], Any], name: Optional[str] = None, daemon: bool = True):
        """Start ``fn`` on a daemon thread; the returned handle supports ``join``."""
        t = threading.Thread(target=fn, name=name, daemon=daemon)
        t.start()
        return t

    def run(self, fn: Callable[[], Any]) -> Any:
        """Execute ``fn`` inline (symmetry with ``VirtualClock.run``)."""
        return fn()


#: Process-wide default clock; module code uses it when none is injected.
SYSTEM_CLOCK = SystemClock()


# Actor states.
_READY, _RUNNING, _SLEEPING, _WAITING, _DONE = range(5)
_STATE_NAMES = {_READY: "ready", _RUNNING: "running", _SLEEPING: "sleeping",
                _WAITING: "waiting", _DONE: "done"}


class _Actor:
    __slots__ = (
        "aid", "name", "daemon", "thread", "state", "wake_time", "notified",
        "resume", "result", "error", "ready_seq",
    )

    def __init__(self, aid: int, name: str, daemon: bool):
        self.aid = aid
        self.name = name
        self.daemon = daemon
        self.thread: Optional[threading.Thread] = None
        self.state = _READY
        self.wake_time: Optional[float] = None
        self.notified = False
        self.resume = threading.Event()
        self.result: Any = None
        self.error: Optional[BaseException] = None
        self.ready_seq = 0


class ActorHandle:
    """Join/result handle for a ``VirtualClock`` actor (Thread-like surface)."""

    def __init__(self, clock: "VirtualClock", actor: _Actor):
        self._clock = clock
        self._actor = actor

    @property
    def name(self) -> str:
        """The actor's diagnostic name."""
        return self._actor.name

    @property
    def done(self) -> bool:
        """True once the actor's function returned or raised."""
        return self._actor.state == _DONE

    def join(self, timeout: Optional[float] = None) -> None:
        """Block the calling actor until this actor finishes (or timeout)."""
        self._clock._join(self._actor, timeout)

    def result(self) -> Any:
        """The actor's return value; re-raises if the actor raised."""
        if self._actor.error is not None:
            raise self._actor.error
        return self._actor.result


class _VirtualCondition:
    """Condition variable whose timed waits run on virtual time.

    ``wait``/``notify`` follow ``threading.Condition`` semantics over a real
    lock (shared critical sections keep working verbatim); only the *timeout*
    is virtual, so a waiting actor parks in the clock's event heap instead of
    the OS scheduler.
    """

    def __init__(self, clock: "VirtualClock", lock: Optional[threading.Lock] = None):
        self._clock = clock
        self._lock = lock if lock is not None else threading.RLock()
        self._waiters: List[_Actor] = []

    # Lock surface (``with cond:`` works like threading.Condition).
    def acquire(self, *a, **kw):
        return self._lock.acquire(*a, **kw)

    def release(self):
        return self._lock.release()

    def __enter__(self):
        self._lock.acquire()
        return self

    def __exit__(self, *exc):
        self._lock.release()
        return False

    def wait(self, timeout: Optional[float] = None) -> bool:
        """Release the lock, park until notified or virtual timeout, reacquire."""
        clock = self._clock
        actor = clock._require_actor("Condition.wait")
        with clock._mutex:
            actor.state = _WAITING
            actor.notified = False
            actor.wake_time = None if timeout is None else clock._now + max(timeout, 0.0)
            self._register(actor)
        self._lock.release()
        try:
            clock._yield_from_actor(actor)
        finally:
            self._lock.acquire()
        with clock._mutex:
            if actor in self._waiters:
                self._waiters.remove(actor)
        return actor.notified

    def notify(self, n: int = 1) -> None:
        """Wake up to ``n`` waiting actors (in wait-arrival order)."""
        clock = self._clock
        with clock._mutex:
            woken = 0
            for a in list(self._waiters):
                if woken >= n:
                    break
                if a.state == _WAITING:
                    a.notified = True
                    clock._make_ready_locked(a)
                    self._waiters.remove(a)
                    woken += 1

    def notify_all(self) -> None:
        """Wake every waiting actor."""
        self.notify(n=len(self._waiters) + 1)

    def _register(self, actor: _Actor) -> None:
        self._waiters.append(actor)


class VirtualClock:
    """Deterministic discrete-event clock (see module docstring).

    The thread that calls :meth:`run` becomes the scheduler: it resumes one
    ready actor at a time (FIFO over a deterministic ready queue) and, when
    none is ready, advances ``now`` to the earliest sleeping/waiting
    deadline.  If no actor is ready, none has a deadline, and the main actor
    has not finished, the run is deadlocked and a diagnostic ``RuntimeError``
    lists every actor's state.
    """

    virtual = True

    def __init__(self):
        self._now = 0.0
        self._mutex = threading.Lock()
        self._actors: List[_Actor] = []
        self._ready: List[_Actor] = []
        self._ready_seq = 0
        self._joiners: Dict[int, List[_Actor]] = {}
        self._current: Optional[_Actor] = None
        self._sched_wake = threading.Event()
        self._running = False

    # ------------------------------------------------------------- surface --
    def monotonic(self) -> float:
        """Current virtual time [s]; starts at 0 and only the scheduler advances it."""
        return self._now

    def sleep(self, dt: float) -> None:
        """Park the calling actor until ``now + dt`` (virtual seconds)."""
        actor = self._require_actor("sleep")
        with self._mutex:
            actor.state = _SLEEPING
            actor.wake_time = self._now + max(dt, 0.0)
            actor.notified = False
        self._yield_from_actor(actor)

    def condition(self, lock: Optional[threading.Lock] = None) -> _VirtualCondition:
        """A condition variable whose timed waits run on virtual time."""
        return _VirtualCondition(self, lock)

    def spawn(
        self, fn: Callable[[], Any], name: Optional[str] = None, daemon: bool = True
    ) -> ActorHandle:
        """Register ``fn`` as a new actor; it runs when the scheduler picks it."""
        with self._mutex:
            actor = _Actor(len(self._actors), name or f"actor-{len(self._actors)}", daemon)
            self._actors.append(actor)
            self._make_ready_locked(actor)
        t = threading.Thread(
            target=self._actor_main, args=(actor, fn), name=actor.name, daemon=True
        )
        actor.thread = t
        t.start()
        return ActorHandle(self, actor)

    def run(self, fn: Callable[[], Any]) -> Any:
        """Drive the event loop until ``fn`` (the main actor) returns.

        Returns ``fn()``'s value; re-raises its exception.  A *background*
        (daemon) actor that raised during the run is re-raised at the end so
        silent crashes in rx/dispatch loops fail tests instead of hanging or
        vanishing.
        """
        if self._running:
            raise RuntimeError("VirtualClock.run is not reentrant")
        self._running = True
        try:
            main = self.spawn(fn, name="main", daemon=False)._actor
            while main.state != _DONE:
                actor = self._pop_ready()
                if actor is not None:
                    self._step(actor)
                    continue
                if not self._advance_time():
                    self._raise_deadlock(main)
            if main.error is not None:
                raise main.error
            for a in self._actors:
                if a.error is not None:
                    raise RuntimeError(
                        f"background actor {a.name!r} raised during the run"
                    ) from a.error
            return main.result
        finally:
            self._running = False

    # ----------------------------------------------------------- internals --
    def _require_actor(self, what: str) -> _Actor:
        actor = self._current
        if actor is None or actor.thread is not threading.current_thread():
            raise RuntimeError(
                f"blocking VirtualClock call ({what}) from outside a clock actor — "
                "wrap the calling code in clock.run(...) or clock.spawn(...)"
            )
        return actor

    def _make_ready_locked(self, actor: _Actor) -> None:
        actor.state = _READY
        actor.wake_time = None
        self._ready_seq += 1
        actor.ready_seq = self._ready_seq
        self._ready.append(actor)

    def _pop_ready(self) -> Optional[_Actor]:
        with self._mutex:
            return self._ready.pop(0) if self._ready else None

    def _step(self, actor: _Actor) -> None:
        """Resume one actor and block until it yields back or finishes."""
        self._current = actor
        actor.state = _RUNNING
        self._sched_wake.clear()
        actor.resume.set()
        self._sched_wake.wait()
        self._current = None

    def _yield_from_actor(self, actor: _Actor) -> None:
        """Actor side of the baton pass: hand control back, wait to be resumed."""
        self._sched_wake.set()
        actor.resume.wait()
        actor.resume.clear()

    def _advance_time(self) -> bool:
        """Jump ``now`` to the earliest deadline and wake those actors.

        Returns False when no actor holds a deadline (deadlock or done).
        """
        with self._mutex:
            pending = [
                a for a in self._actors
                if a.state in (_SLEEPING, _WAITING) and a.wake_time is not None
            ]
            if not pending:
                return False
            t = min(a.wake_time for a in pending)
            self._now = max(self._now, t)
            for a in sorted(pending, key=lambda a: (a.wake_time, a.aid)):
                if a.wake_time <= self._now:
                    # Timed-out waiters resume un-notified (wait() -> False).
                    self._make_ready_locked(a)
            return True

    def _join(self, target: _Actor, timeout: Optional[float]) -> None:
        actor = self._require_actor("join")
        with self._mutex:
            if target.state == _DONE:
                return
            self._joiners.setdefault(target.aid, []).append(actor)
            actor.state = _WAITING
            actor.notified = False
            actor.wake_time = None if timeout is None else self._now + max(timeout, 0.0)
        self._yield_from_actor(actor)
        with self._mutex:
            joiners = self._joiners.get(target.aid, [])
            if actor in joiners:  # timed out before the target finished
                joiners.remove(actor)

    def _actor_main(self, actor: _Actor, fn: Callable[[], Any]) -> None:
        actor.resume.wait()  # first schedule
        actor.resume.clear()
        try:
            actor.result = fn()
        except BaseException as e:  # noqa: BLE001 - re-raised by run()
            actor.error = e
        with self._mutex:
            actor.state = _DONE
            for j in self._joiners.pop(actor.aid, []):
                # A joiner whose timeout fired in the same time-advance is
                # already READY — re-readying it would deliver a spurious
                # resume that corrupts its next blocking call.
                if j.state == _WAITING:
                    j.notified = True
                    self._make_ready_locked(j)
        self._sched_wake.set()

    def _raise_deadlock(self, main: _Actor) -> None:
        states = ", ".join(
            f"{a.name}={_STATE_NAMES[a.state]}"
            + (f"@{a.wake_time:.3f}" if a.wake_time is not None else "")
            for a in self._actors
            if a.state != _DONE
        )
        raise RuntimeError(
            f"virtual-clock deadlock at t={self._now:.3f}: no actor is ready and "
            f"none holds a wake deadline ({states}) — a wait without timeout is "
            "blocked on an event that can no longer happen"
        )
