"""Fleet autoscaling policy: occupancy/queue-depth signals -> scale decisions.

Like placement (``runtime/placement.py``), scaling is a *pure* policy: the
router's control loop snapshots the fleet into ``VerifierLoad`` records and
asks :class:`AutoScaler` for a :class:`ScaleDecision`.  The scaler never
spawns or stops verifiers itself — the router owns the mechanics (spawn via
its ``make_verifier`` factory, retire via drain + migrate-away) — so the
policy is deterministic and directly unit-testable on synthetic loads.

Signals (thresholds in :class:`ScalingConfig`):

* scale **up** when the mean verify-queue depth, the mean session occupancy,
  or the worst KV free-fraction crosses its high-water mark;
* scale **down** when the fleet would comfortably fit on one fewer verifier,
  draining the least-loaded member (fewest sessions to migrate away);
* decisions are cooldown-gated so one burst cannot thrash the fleet.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

from .placement import VerifierLoad

__all__ = ["ScalingConfig", "ScaleDecision", "AutoScaler"]


@dataclass(frozen=True)
class ScalingConfig:
    """Thresholds and bounds for :class:`AutoScaler`.

    ``sessions_high`` is the per-verifier occupancy above which the fleet
    scales up; ``queue_high`` the mean queue depth trigger;
    ``free_frac_low`` the KV free-fraction floor; ``sessions_low_factor``
    the headroom multiplier required before scaling down (the fleet must fit
    on ``n - 1`` verifiers at ``sessions_low_factor * sessions_high``
    occupancy); ``cooldown`` the minimum spacing between decisions, in
    clock seconds.
    """

    min_verifiers: int = 1
    max_verifiers: int = 8
    sessions_high: float = 8.0
    queue_high: float = 4.0
    free_frac_low: float = 0.10
    sessions_low_factor: float = 0.5
    cooldown: float = 2.0


@dataclass(frozen=True)
class ScaleDecision:
    """Outcome of one control tick: ``action`` is 'up', 'down', or 'hold'.

    For 'down', ``drain`` names the verifier to retire (drain + migrate its
    sessions away); ``reason`` is a human-readable trigger description.
    """

    action: str
    drain: Optional[int] = None
    reason: str = ""


_HOLD = ScaleDecision("hold")


class AutoScaler:
    """Cooldown-gated threshold scaler over fleet load snapshots.

    ``decide`` is deterministic in (loads, now, prior decisions): the only
    internal state is the timestamp of the last non-hold decision, used for
    cooldown gating.
    """

    def __init__(self, cfg: Optional[ScalingConfig] = None) -> None:
        """Create a scaler with ``cfg`` thresholds (defaults when ``None``)."""
        self.cfg = cfg or ScalingConfig()
        self._last_action_at: Optional[float] = None

    def decide(self, loads: Sequence[VerifierLoad], now: float) -> ScaleDecision:
        """Return the scale action for the fleet snapshot ``loads`` at ``now``."""
        cfg = self.cfg
        active = [ld for ld in loads if ld.alive and not ld.draining]
        n = len(active)
        if n == 0:
            # A dead fleet always warrants a replacement (ignores cooldown:
            # there is nothing left to thrash).
            self._last_action_at = now
            return ScaleDecision("up", reason="no active verifiers")
        if (
            self._last_action_at is not None
            and now - self._last_action_at < cfg.cooldown
        ):
            return _HOLD
        total_sessions = sum(ld.sessions for ld in active)
        mean_queue = sum(ld.queue_depth for ld in active) / n
        min_free_frac = min(ld.free_fraction for ld in active)
        if n < cfg.max_verifiers:
            if mean_queue > cfg.queue_high:
                self._last_action_at = now
                return ScaleDecision(
                    "up", reason=f"mean queue {mean_queue:.1f} > {cfg.queue_high}"
                )
            if total_sessions > cfg.sessions_high * n:
                self._last_action_at = now
                return ScaleDecision(
                    "up",
                    reason=f"{total_sessions} sessions > "
                    f"{cfg.sessions_high:.0f} per verifier",
                )
            if min_free_frac < cfg.free_frac_low:
                self._last_action_at = now
                return ScaleDecision(
                    "up",
                    reason=f"KV free fraction {min_free_frac:.2f} < "
                    f"{cfg.free_frac_low}",
                )
        if (
            n > cfg.min_verifiers
            and mean_queue <= 1.0
            and total_sessions
            <= cfg.sessions_high * cfg.sessions_low_factor * (n - 1)
        ):
            victim = min(active, key=lambda ld: (ld.sessions, ld.queue_depth, ld.verifier))
            self._last_action_at = now
            return ScaleDecision(
                "down",
                drain=victim.verifier,
                reason=f"{total_sessions} sessions fit on {n - 1} verifiers",
            )
        return _HOLD
