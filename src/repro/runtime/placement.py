"""Session -> verifier placement policy for the multi-verifier control plane.

The router (``runtime/router.py``) fronts a fleet of ``CloudVerifier``
instances and must decide, per arriving session, which verifier admits it.
This module keeps that decision *pure*: the router snapshots each fleet
member into a :class:`VerifierLoad` and hands the list to a
:class:`PlacementPolicy`, which returns a verifier id or ``None`` (admission
refusal).  Policies never touch transports or clocks, so they are unit- and
property-testable in isolation (``tests/test_router.py``).

The default :class:`LeastLoadedPlacement` implements the paper-adjacent
serving heuristic: among alive, non-draining verifiers with enough free
paged-KV blocks for the new session, pick the one with the fewest placed
sessions, breaking ties by shallower verify queue, then by more free KV
blocks, then by lowest id (for determinism).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

__all__ = ["VerifierLoad", "PlacementPolicy", "LeastLoadedPlacement"]


@dataclass(frozen=True)
class VerifierLoad:
    """Point-in-time load snapshot of one fleet member.

    ``free_blocks``/``capacity_blocks`` are ``None`` when the verifier runs
    without a paged-KV pool (unbounded); ``queue_depth`` is the verify-queue
    length (fractional values allowed for smoothed estimates).
    """

    verifier: int
    sessions: int = 0
    queue_depth: float = 0.0
    free_blocks: Optional[int] = None
    capacity_blocks: Optional[int] = None
    draining: bool = False
    alive: bool = True

    @property
    def free_fraction(self) -> float:
        """Fraction of KV capacity still free (1.0 when unbounded)."""
        if self.free_blocks is None or not self.capacity_blocks:
            return 1.0
        return self.free_blocks / self.capacity_blocks


class PlacementPolicy:
    """Interface: map a fleet load snapshot to an admitting verifier id."""

    def place(
        self, loads: Sequence[VerifierLoad], need_blocks: int = 0
    ) -> Optional[int]:
        """Return the verifier id to place on, or ``None`` to refuse.

        ``need_blocks`` is the paged-KV block headroom the new session
        requires; a verifier whose ``free_blocks`` is below it is never
        eligible (the property test in ``tests/test_router.py`` enforces
        this budget invariant for every policy).
        """
        raise NotImplementedError


@dataclass
class LeastLoadedPlacement(PlacementPolicy):
    """Least-loaded admission with a KV-free-block tiebreak.

    Eligibility: alive, not draining, and ``free_blocks`` (when bounded)
    covers ``need_blocks``.  Selection key, in order: fewest sessions,
    shallowest queue, most free KV blocks, lowest verifier id.
    """

    def admissible(self, load: VerifierLoad, need_blocks: int = 0) -> bool:
        """True when ``load`` may admit a session needing ``need_blocks``."""
        if not load.alive or load.draining:
            return False
        return load.free_blocks is None or load.free_blocks >= need_blocks

    def place(
        self, loads: Sequence[VerifierLoad], need_blocks: int = 0
    ) -> Optional[int]:
        """Pick the least-loaded admissible verifier (``None`` if fleet full)."""
        candidates = [ld for ld in loads if self.admissible(ld, need_blocks)]
        if not candidates:
            return None
        best = min(
            candidates,
            key=lambda ld: (
                ld.sessions,
                ld.queue_depth,
                -(ld.free_blocks if ld.free_blocks is not None else float("inf")),
                ld.verifier,
            ),
        )
        return best.verifier
