"""Cloud verifier service (the paper's FastAPI server, §4.2, App. I).

A continuous-batching dispatcher serves any number of edge sessions
(beyond-paper optimization #5 — the cross-request analogue of the paper's
§3.2 resource-utilization argument, in the spirit of FlowSpec/DiP-SD):

* buffers draft tokens per session as batches stream in (pipelined upload);
* a NAV request whose tokens are not all buffered yet is parked on the
  session and dispatched the moment the remaining proactively-uploaded
  drafts arrive;
* requests that arrive within ``batch_window`` of each other coalesce into
  ONE padded backend call (``verify_batch``), amortizing the target forward
  across clients — the batched path runs through
  ``kernels.spec_verify.spec_verify_batched`` when a JAX backend is used;
* admission control: at most ``max_batch`` requests per backend call, with
  **fair reinsertion** — when oversubscribed, the least-recently-served
  sessions go first, so long-draft sessions cannot starve short ones;
* straggler mitigation: requests carry client deadlines; work whose deadline
  has already passed (the client has failed over to local decoding) and work
  for sessions that disconnected is dropped, not verified;
* tree speculation: a ``TreeNavRequest`` round's draft fragments carry packed
  tree parents alongside their tokens; tree requests ride the same buffers,
  admission control, and coalescing window as chains, and are padded by NODE
  count through ``spec_verify_tree_batched`` (one ancestor-masked launch per
  dispatch).  Results additionally carry the accepted root→leaf ``path``;
* paged target KV (``kv_pool``): the verifier's per-session cache state
  lives in a ``models.paged_kv.PagedKVPool`` — sessions fork from a shared
  system-prefix session copy-on-write, each verify appends the round's
  ``K+1`` positions and the rejection rollback releases whole pages back to
  the pool.  Admission is additionally gated on the free-block budget: a
  request whose KV growth the pool cannot back first tries to reclaim pages
  from the least-recently-active idle session (``evict_lru``), then parks
  back at the queue head (``kv_parked`` stat) until rollbacks free pages.

Per-dispatch batch size, queue depth, and KV-pool residency are fed to an
``EnvironmentMonitor`` (core.monitor) so benchmarks can lift verifier
occupancy/queue-depth/KV-residency into ``RunStats`` (core.pipeline).

The backend is pluggable: ``SyntheticBackend`` (trace-driven acceptance, used
by benchmarks), or ``SpecVerifyBackend`` running the real fused NAV kernel
(Pallas on TPU, pure-JAX ``ref`` on CPU), optionally with a batched paged
target forward (``batched_logits_fn`` + the sessions' KV block tables).
"""

from __future__ import annotations

import threading
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Deque, Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core.monitor import EnvironmentMonitor
from repro.models.paged_kv import BlockPoolExhausted, PagedKVPool
from repro.obs.trace import NULL_TRACER
from .protocol import (
    Detach,
    DraftFragment,
    Drain,
    Hello,
    NavRequest,
    NavResult,
    Reset,
    TelemetryRequest,
    TelemetrySnapshot,
    TreeNavRequest,
    handshake_reply,
)
from .simclock import SYSTEM_CLOCK
from .transport import Transport

__all__ = [
    "VerifyBackend",
    "SyntheticBackend",
    "SpecVerifyBackend",
    "ShardedSpecVerifyBackend",
    "CloudVerifier",
    "VerifierDraining",
]


class VerifierDraining(RuntimeError):
    """Raised by ``CloudVerifier.attach`` when the verifier is draining."""


class VerifyBackend:
    """Interface: verify a session's drafted tokens → (n_accepted, correction)."""

    #: Positional backends are stateless: the dispatcher routes them through
    #: ``verify_batch_pos`` with the stream position each NAV request carries.
    positional: bool = False

    def verify(self, session: int, tokens: List[int], confs: List[float]):  # pragma: no cover
        """Verify one session's chain drafts → ``(n_accepted, correction)``."""
        raise NotImplementedError

    def verify_batch(self, requests: Sequence[Tuple[int, List[int], List[float]]]):
        """Verify many sessions in one call; default loops over ``verify``."""
        return [self.verify(s, t, c) for (s, t, c) in requests]

    def verify_batch_pos(
        self, requests: Sequence[Tuple[int, List[int], List[float], Optional[int]]]
    ):  # pragma: no cover
        """Positional batch verify ``[(session, tokens, confs, pos)]``.

        Only meaningful on ``positional`` backends (``runtime.oracle``).
        """
        raise NotImplementedError

    def verify_tree(self, session: int, tokens: List[int], confs: List[float], parents: List[int]):
        """Tree request → (n_accepted, correction, path-node-indices)."""
        raise NotImplementedError  # pragma: no cover

    def verify_tree_batch(
        self, requests: Sequence[Tuple[int, List[int], List[float], List[int]]]
    ):
        """Verify many sessions' token trees; default loops over ``verify_tree``."""
        return [self.verify_tree(s, t, c, p) for (s, t, c, p) in requests]


@dataclass
class SyntheticBackend(VerifyBackend):
    """Acceptance ~ conf^kappa per token (matches core.pipeline.SyntheticSource).

    ``verify_batch`` models the batched target forward: ONE padded pass whose
    cost scales with the *longest* draft in the batch, not the sum — this is
    the amortization the continuous-batching dispatcher exists to exploit.
    """

    kappa: float = 0.8
    seed: int = 0
    verify_time: float = 0.080  # simulated target forward time [s]
    verify_time_per_token: float = 0.004
    time_scale: float = 1.0
    clock: Any = None  # simclock surface; None -> SYSTEM_CLOCK

    def __post_init__(self) -> None:
        self._rng = np.random.default_rng(self.seed)
        if self.clock is None:
            self.clock = SYSTEM_CLOCK

    def _accept(self, confs: List[float]) -> Tuple[int, int]:
        n_acc = 0
        for c in confs:
            if self._rng.random() < c**self.kappa:
                n_acc += 1
            else:
                break
        correction = int(self._rng.integers(0, 1 << 16))
        return n_acc, correction

    def verify(self, session: int, tokens: List[int], confs: List[float]):
        """One simulated target forward for one session's chain drafts."""
        self.clock.sleep((self.verify_time + self.verify_time_per_token * len(tokens)) * self.time_scale)
        return self._accept(confs)

    def verify_batch(self, requests):
        """One padded pass: cost scales with the longest draft, not the sum."""
        if not requests:
            return []
        max_len = max(len(t) for (_, t, _) in requests)
        self.clock.sleep((self.verify_time + self.verify_time_per_token * max_len) * self.time_scale)
        return [self._accept(c) for (_, _, c) in requests]

    def _accept_tree(self, confs: List[float], parents: List[int]) -> Tuple[int, int, List[int]]:
        """Per-node accept draw w.p. conf^kappa, conditioned on the parent.

        The accepted path is the deepest chain of accepting nodes; siblings
        are tried in packed order, so the tree wins whenever ANY branch at a
        level accepts — the accepted-tokens-per-NAV edge over a chain.
        """
        n = len(confs)
        children: List[List[int]] = [[] for _ in range(n + 1)]
        for i, p in enumerate(parents):
            children[p + 1].append(i)
        path: List[int] = []
        cur = 0  # anchor
        while True:
            nxt = None
            for c in children[cur]:
                if self._rng.random() < confs[c] ** self.kappa:
                    nxt = c
                    break
            if nxt is None:
                break
            path.append(nxt)
            cur = nxt + 1
        correction = int(self._rng.integers(0, 1 << 16))
        return len(path), correction, path

    def verify_tree(self, session, tokens, confs, parents):
        """One simulated tree-NAV call (cost scales with the node count)."""
        self.clock.sleep((self.verify_time + self.verify_time_per_token * len(tokens)) * self.time_scale)
        return self._accept_tree(confs, parents)

    def verify_tree_batch(self, requests):
        """One padded tree pass: cost scales with the largest node count."""
        if not requests:
            return []
        max_len = max(len(t) for (_, t, _, _) in requests)
        self.clock.sleep((self.verify_time + self.verify_time_per_token * max_len) * self.time_scale)
        return [self._accept_tree(c, p) for (_, _, c, p) in requests]


class SpecVerifyBackend(VerifyBackend):
    """Real NAV verification through the fused spec_verify kernel.

    ``logits_fn(session, tokens) -> [len(tokens)+1, V]`` produces the target
    logits for one session (a model forward in a real deployment, a seeded
    synthetic sampler in tests).  ``verify_batch`` pads the ragged requests
    and runs them through ``spec_verify_batched`` in ONE launch — Pallas on
    TPU (``impl='pallas'``), interpret mode or the pure-JAX ``ref`` on CPU.

    **Paged target forward.**  With ``batched_logits_fn`` (and a ``kv_pool``
    supplying per-session KV block tables) the per-session ``logits_fn``
    calls are replaced by ONE batched forward over the padded
    ``(tokens, n_drafted, block_tables)`` arrays — the fused
    paged-attention + NAV dispatch shape a production verifier compiles
    (see ``kernels.spec_verify.spec_verify_batched``).  Ragged tables pad
    with the pool's zero-filled sentinel page (id ``num_blocks``), so a
    padded lane can never prefetch KV owned by another session — a
    ``batched_logits_fn`` gathering from its OWN page buffers must size
    them ``num_blocks + 1`` with a zeroed last page to honour that pad id
    (see ``PagedKVPool.table``).

    **Fused one-launch verify** (``fused=True``).  Requires a TENSOR-mode
    ``kv_pool``, a ``query_fn(session, tokens) -> [K+1, H, hd]`` producing
    the target's per-position queries, and ``lm_head [H*hd, V]``: chain
    rounds then run ``spec_verify_fused_batched`` — paged attention over
    the sessions' block tables + LM-head projection + NAV scan in ONE
    Pallas launch instead of forward-then-verify.  The round's KV slots
    (metadata-appended by the dispatcher's ``_kv_secure``) are materialized
    through ``kv_fn(session, start, count) -> (k, v)`` just before the
    launch, from the pool's per-session ``filled`` watermark (``ensure_kv``)
    — so slots regrown after a rollback or eviction are always refilled,
    never trusted to still hold this session's tensors.  The default
    ``kv_fn`` synthesizes deterministic position-keyed tensors, so
    re-prefills reproduce the original values bit-for-bit.  A shared-prefix
    ``CloudVerifier`` materializes the prefix ONCE on its owner session
    before any fork; children inherit the watermark and never fill shared
    pages (``PagedKVPool.fill`` would CoW-copy them, forfeiting the
    sharing).  An int8 pool (``quantize='int8'``) is picked up
    automatically — the launch dequantizes pages in-kernel.
    """

    def __init__(
        self,
        logits_fn: Optional[Callable] = None,
        impl: str = "ref",
        block_v: int = 2048,
        kv_pool: Optional[PagedKVPool] = None,
        batched_logits_fn: Optional[Callable] = None,
        batched_tree_logits_fn: Optional[Callable] = None,
        fused: bool = False,
        query_fn: Optional[Callable] = None,
        lm_head: Optional[Any] = None,
        kv_fn: Optional[Callable] = None,
    ):
        if fused:
            if kv_pool is None or kv_pool.k_pages is None:
                raise ValueError("fused=True needs a tensor-mode kv_pool")
            if query_fn is None or lm_head is None:
                raise ValueError("fused=True needs query_fn and lm_head")
        elif logits_fn is None and batched_logits_fn is None:
            raise ValueError("need logits_fn or batched_logits_fn")
        self.logits_fn = logits_fn
        self.impl = impl
        self.block_v = block_v
        self.kv_pool = kv_pool
        self.batched_logits_fn = batched_logits_fn
        self.batched_tree_logits_fn = batched_tree_logits_fn
        self.fused = fused
        self.query_fn = query_fn
        self.lm_head = lm_head
        self.kv_fn = kv_fn if kv_fn is not None else self._default_kv_fn

    def _tables(self, sessions: Sequence[int]):
        if self.kv_pool is None:
            return None
        return [
            list(self.kv_pool.table(s)) if s in self.kv_pool.tables else []
            for s in sessions
        ]

    @property
    def _pad_page_id(self) -> int:
        return self.kv_pool.sentinel_page if self.kv_pool is not None else 0

    def _default_kv_fn(self, session: int, start: int, count: int):
        """Deterministic position-keyed synthetic KV (the modeled target).

        Keyed by POSITION only — not session — so CoW-shared prefix pages
        hold the same values no matter which session materializes them, and
        re-prefills after eviction/rollback reproduce the original tensors
        bit-for-bit.
        """
        pool = self.kv_pool
        shape = (pool.n_layers, count, pool.n_kv_heads, pool.head_dim)
        pos = start + np.arange(count, dtype=np.float32)
        phase = np.arange(
            pool.n_layers * pool.n_kv_heads * pool.head_dim, dtype=np.float32
        ).reshape(pool.n_layers, 1, pool.n_kv_heads, pool.head_dim)
        base = np.sin(pos[None, :, None, None] * 0.37 + phase * 0.11).astype(np.float32)
        return np.reshape(base, shape), np.reshape(np.roll(base, 1, axis=-1) * 0.5, shape)

    def ensure_kv(self, session: int) -> None:
        """Materialize tensors for every slot past the pool's filled watermark.

        The pool's per-session ``filled`` watermark is authoritative — NOT a
        backend-side counter: rollback lowers it past rejected positions
        (whose replacements may land in recycled physical pages holding
        another session's data), eviction zeroes it, and it dies with the
        table on release, so re-grown or re-registered sessions always
        refill from their true materialized prefix.
        """
        pool = self.kv_pool
        have = pool.filled(session)
        need = pool.length(session)
        if need > have:
            k, v = self.kv_fn(session, have, need - have)
            pool.fill(session, have, k, v)

    def verify(self, session: int, tokens: List[int], confs: List[float]):
        """Verify one session through the batched path (batch of one)."""
        return self.verify_batch([(session, tokens, confs)])[0]

    def verify_batch(self, requests):
        """Pad the ragged requests and run ONE fused NAV kernel launch."""
        if not requests:
            return []
        from repro.kernels.spec_verify import spec_verify_batched

        tokens = [t for (_, t, _) in requests]
        if self.fused:
            return self._verify_batch_fused(requests)
        if self.batched_logits_fn is not None:
            out = spec_verify_batched(
                None,
                tokens,
                impl=self.impl,
                block_v=self.block_v,
                block_tables_seq=self._tables([s for (s, _, _) in requests]),
                batched_logits_fn=self.batched_logits_fn,
                pad_page_id=self._pad_page_id,
            )
        else:
            logits = [self.logits_fn(s, t) for (s, t, _) in requests]
            out = spec_verify_batched(logits, tokens, impl=self.impl, block_v=self.block_v)
        return [(int(n_acc), int(corr)) for (n_acc, corr, _) in out]

    def _verify_batch_fused(self, requests):
        """ONE launch for the whole round: attention + LM head + NAV scan.

        Fills any unmaterialized KV slots (the dispatcher appends page
        metadata in ``_kv_secure`` before we run), then hands queries, block
        tables, page tensors (+ int8 quant params when the pool quantizes),
        and the LM head to ``spec_verify_fused_batched``.
        """
        from repro.kernels.spec_verify import spec_verify_fused_batched

        pool = self.kv_pool
        sessions = [s for (s, _, _) in requests]
        for s in sessions:
            self.ensure_kv(s)
        tokens = [t for (_, t, _) in requests]
        q_seq = [np.asarray(self.query_fn(s, t), np.float32) for (s, t, _) in requests]
        base = [max(pool.length(s) - len(t), 0) for (s, t, _) in requests]
        quant = None
        if pool.quantize == "int8":
            quant = (pool.k_scale[0], pool.k_zero[0], pool.v_scale[0], pool.v_zero[0])
        out = spec_verify_fused_batched(
            q_seq,
            tokens,
            self._tables(sessions),
            base,
            pool.k_pages[0],
            pool.v_pages[0],
            self.lm_head,
            impl=self.impl,
            block_v=self.block_v,
            pad_page_id=pool.sentinel_page,
            quant=quant,
        )
        return [(int(n_acc), int(corr)) for (n_acc, corr, _) in out]

    def verify_tree(self, session, tokens, confs, parents):
        """Verify one session's tree through the batched path (batch of one)."""
        return self.verify_tree_batch([(session, tokens, confs, parents)])[0]

    def verify_tree_batch(self, requests):
        """One padded tree-NAV launch over the batch (pad by node count).

        ``logits_fn(session, tokens)`` must return ``[len(tokens)+1, V]`` rows
        in packed-tree order (row 0 anchor, row 1+i node i) when the request
        is a tree — the same contract ``tree_target_logits`` produces.
        """
        if not requests:
            return []
        from repro.kernels.spec_verify import spec_verify_tree_batched

        tokens = [t for (_, t, _, _) in requests]
        parents = [p for (_, _, _, p) in requests]
        if self.batched_tree_logits_fn is not None:
            out = spec_verify_tree_batched(
                None,
                tokens,
                parents,
                impl=self.impl,
                block_v=self.block_v,
                block_tables_seq=self._tables([s for (s, _, _, _) in requests]),
                batched_logits_fn=self.batched_tree_logits_fn,
                pad_page_id=self._pad_page_id,
            )
        elif self.logits_fn is None:
            raise ValueError(
                "tree requests need logits_fn or batched_tree_logits_fn "
                "(this backend was built with only a chain batched_logits_fn)"
            )
        else:
            logits = [self.logits_fn(s, t) for (s, t, _, _) in requests]
            out = spec_verify_tree_batched(
                logits, tokens, parents, impl=self.impl, block_v=self.block_v
            )
        return [(int(n_acc), int(corr), list(path)) for (n_acc, path, corr, _) in out]


class ShardedSpecVerifyBackend(SpecVerifyBackend):
    """Tensor-parallel fused verify: the same one-launch contract, sharded.

    Drop-in for ``SpecVerifyBackend(fused=True)``: chain rounds run the
    SHARDED fused launch (``repro.sharding.spec_verify``) across a 1-D
    ``("model",)`` device mesh — head-parallel paged attention,
    vocab-parallel LM head, replicated NAV scan — while the dispatcher,
    router, and every protocol message stay oblivious to the shard count.
    The pool's page buffers are laid out over the mesh on construction
    (``PagedKVPool.place_on_mesh``: head axis when divisible, replicated
    otherwise) and block tables are replicated per device at launch, so the
    sentinel-page padding contract holds on every shard.  Bit-exact against
    the unsharded backend (``tests/test_sharded_verify.py``) for fp32 and
    int8 pools, including GQA head counts that don't divide the mesh.

    Pass either a prebuilt ``mesh`` or a ``shards`` count; the latter builds
    a host mesh over the first ``shards`` visible devices (set
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` for CPU runs).
    """

    def __init__(self, *, shards: int = 1, mesh: Any = None, **kwargs: Any):
        kwargs.setdefault("fused", True)
        if not kwargs["fused"]:
            raise ValueError("ShardedSpecVerifyBackend requires the fused path")
        super().__init__(**kwargs)
        from repro.sharding.shardctx import host_mesh

        self.mesh = mesh if mesh is not None else host_mesh(int(shards))
        self.shards = int(np.prod(list(self.mesh.shape.values())))
        if self.kv_pool is not None:
            self.kv_pool.place_on_mesh(self.mesh)

    def _verify_batch_fused(self, requests):
        """ONE SHARDED launch for the whole round (see the unsharded twin)."""
        from repro.sharding.spec_verify import spec_verify_sharded_batched

        pool = self.kv_pool
        sessions = [s for (s, _, _) in requests]
        for s in sessions:
            self.ensure_kv(s)
        tokens = [t for (_, t, _) in requests]
        q_seq = [np.asarray(self.query_fn(s, t), np.float32) for (s, t, _) in requests]
        base = [max(pool.length(s) - len(t), 0) for (s, t, _) in requests]
        quant = None
        if pool.quantize == "int8":
            quant = (pool.k_scale[0], pool.k_zero[0], pool.v_scale[0], pool.v_zero[0])
        out = spec_verify_sharded_batched(
            q_seq,
            tokens,
            self._tables(sessions),
            base,
            pool.k_pages[0],
            pool.v_pages[0],
            self.lm_head,
            mesh=self.mesh,
            block_v=self.block_v,
            pad_page_id=pool.sentinel_page,
            quant=quant,
        )
        return [(int(n_acc), int(corr)) for (n_acc, corr, _) in out]


@dataclass
class _VerifyRequest:
    session: int
    tokens: List[int]
    confs: List[float]
    msg: NavRequest  # the originating (typed) request; its seq keys the reply
    t_enqueue: float
    deadline: Optional[float]  # absolute monotonic; None = never drop
    parents: Optional[List[int]] = None  # packed tree parents; None = chain
    kv_secured: bool = False  # this dispatch appended the round's KV pages
    pos: Optional[int] = None  # client stream position of the round's first draft
    epoch: int = 0  # session reset-epoch at enqueue; stale epochs never commit


@dataclass
class _Session:
    # Draft buffers keyed by the client's round id. Per-round keying makes
    # message loss recoverable: a round whose drafts were partially dropped
    # parks and is eventually abandoned WITHOUT consuming the next round's
    # tokens, so one lost draft_batch cannot desync the whole session.
    # Round-less (legacy) messages all land in round 0 and behave like a
    # single shared buffer.  The third buffer lane carries packed tree
    # parents (absolute node indices within the round); chain rounds leave
    # it empty.
    # Per-round draft fragments keyed by message seq.  Flattening in seq
    # order reassembles the client's draft order even when batches arrive
    # reorder-delayed, so the verifier never evaluates a scrambled round.
    buffers: Dict[int, Dict[int, Tuple[List[int], List[float], List[int]]]] = field(
        default_factory=dict
    )
    # NAV round that arrived before its proactively-uploaded drafts did.
    pending_request: Optional[NavRequest] = None
    last_seen: float = 0.0
    served: int = 0  # rounds verified — fairness key for admission
    kv_committed: int = 0  # logical target-cache length (tokens committed)
    # Duplicate suppression under retransmission faults: message seqs already
    # folded into each round's buffer (dropped with the buffer), and the
    # highest round id already enqueued for dispatch (a duplicated
    # nav_request must not verify — and KV-commit — the round twice).
    buf_seqs: Dict[int, Set[int]] = field(default_factory=dict)
    max_round_enqueued: int = 0
    # Bumped by re-attach reconciliation; an in-flight round enqueued under
    # an older epoch was abandoned by the edge and must not commit.
    epoch: int = 0

    def buf(self, rnd: int) -> Tuple[List[int], List[float], List[int]]:
        """The round's (tokens, confs, parents), flattened in seq order."""
        toks: List[int] = []
        confs: List[float] = []
        pars: List[int] = []
        frags = self.buffers.get(rnd, {})
        for seq in sorted(frags):
            t, c, p = frags[seq]
            toks.extend(t)
            confs.extend(c)
            pars.extend(p)
        return toks, confs, pars


class CloudVerifier:
    """Continuous-batching dispatcher over (uplink, downlink) pairs per session.

    With ``kv_pool`` the verifier also manages per-session target KV state in
    a paged block pool: sessions fork from a ``kv_shared_prefix``-token
    common prefix (CoW), each dispatch appends the round's ``K+1`` cache
    positions, and the post-verify rollback releases rejected pages.
    ``kv_flat_reserve`` instead reserves that many contiguous token slots per
    session up front — the flat-cache baseline, inside the same pool
    accounting so paged-vs-flat residency is directly comparable.
    """

    #: Pool session id owning the shared system/prompt prefix pages.
    KV_PREFIX_SESSION = -1

    def __init__(
        self,
        backend: VerifyBackend,
        batch_window: float = 0.0,  # >0 → coalesce concurrent NAV requests
        session_timeout: float = 30.0,
        max_batch: Optional[int] = None,
        drop_expired: bool = True,
        monitor_window: int = 1_000_000,
        kv_pool: Optional[PagedKVPool] = None,
        kv_shared_prefix: int = 0,
        kv_flat_reserve: Optional[int] = None,
        clock=None,
        tracer=None,
        metrics=None,
        verifier_id: int = 0,
    ):
        self.clock = clock or SYSTEM_CLOCK
        self.backend = backend
        # Observability (repro.obs): span tracer + metric registry, both
        # no-ops by default — tracing/metrics are strictly opt-in so the
        # serving hot path pays one attribute check when disabled.
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.metrics = metrics
        self.verifier_id = int(verifier_id)
        self.batch_window = batch_window
        self.session_timeout = session_timeout
        self.kv_pool = kv_pool
        self.kv_shared_prefix = int(kv_shared_prefix)
        self.kv_flat_reserve = kv_flat_reserve
        if kv_pool is not None and kv_flat_reserve is None and self.kv_shared_prefix > 0:
            kv_pool.create(self.KV_PREFIX_SESSION)
            kv_pool.append(self.KV_PREFIX_SESSION, self.kv_shared_prefix)
            # Tensor-filling backends materialize the prefix ONCE, on its
            # owner, BEFORE any session forks it: children then inherit the
            # pool's filled watermark and only ever fill their own pages —
            # filling through a forked table would CoW-copy every shared
            # prefix page (pool.fill diverges shared pages), forfeiting the
            # prefix-sharing win.
            if (
                getattr(backend, "fused", False)
                and getattr(backend, "kv_pool", None) is kv_pool
            ):
                backend.ensure_kv(self.KV_PREFIX_SESSION)
        # Default: batching only when a coalescing window was requested.
        # batch_window == 0 keeps strict per-session serving (one request per
        # backend call, summed costs) so baselines measure what they claim.
        if max_batch is None:
            max_batch = 32 if batch_window > 0 else 1
        self.max_batch = max(int(max_batch), 1)
        self.drop_expired = drop_expired
        self.draining = False  # set by drain(): attach refuses new sessions
        self.links: Dict[int, tuple] = {}  # session -> (uplink, downlink)
        self.sessions: Dict[int, _Session] = {}
        self.stats = {
            "nav_calls": 0,
            "tokens_verified": 0,
            "accepted_tokens": 0,  # accepted DRAFT tokens (corrections excluded)
            "batched_calls": 0,
            "dropped_stragglers": 0,
            "dropped_dead_sessions": 0,
            "max_queue_depth": 0,
            # Paged-KV pressure: admissions deferred for lack of free pages,
            # and flat reservations that saturated (the flat cache's hard cap).
            "kv_parked": 0,
            "kv_cap_hits": 0,
            # Clock seconds the backend spent inside verify calls — the busy
            # time the energy model charges at (p_active − p_idle) watts.
            "verify_busy_time": 0.0,
        }
        # The monitor here is an accumulator for the whole serving run, not
        # the paper's 100-observation estimator — size the window accordingly
        # so benchmark occupancy/queue series are not tail-truncated.
        self.monitor = EnvironmentMonitor(window=monitor_window)
        self._stop = threading.Event()
        self._threads: List = []  # clock spawn handles (Thread or ActorHandle)
        self._lock = threading.Lock()
        self._work = self.clock.condition(self._lock)
        self._queue: Deque[_VerifyRequest] = deque()

    def attach(self, session: int, uplink: Transport, downlink: Transport) -> None:
        """Register a session and start its receive loop.

        With a flat-reserve KV pool the up-front contiguous reservation
        happens here and ``BlockPoolExhausted`` propagates to the caller —
        the flat baseline's hard admission limit.  Paged sessions instead
        fork the shared prefix copy-on-write (no pages allocated).

        Raises ``VerifierDraining`` while draining (the control plane must
        place new sessions elsewhere).  Re-attaching an existing session id
        (router restart / migration replay) supersedes the old links: the old
        receive loop ends, the old epoch's in-flight rounds never commit, and
        the session keeps its KV pages and committed position until the
        follow-up ``Reset`` reconciles them.
        """
        with self._lock:
            if self.draining:
                raise VerifierDraining(f"draining: session {session} refused")
            old = self.sessions.get(session)
            if old is not None:
                old_up, _ = self.links[session]
                old_up.close()  # ends the superseded receive loop
            sess = _Session(last_seen=self.clock.monotonic())
            if old is not None:
                sess.epoch = old.epoch + 1
                sess.kv_committed = old.kv_committed
                sess.served = old.served
            if self.kv_pool is not None:
                if session not in self.kv_pool.tables:
                    self._kv_register(session)
                if (
                    old is None
                    and self.kv_flat_reserve is None
                    and self.kv_shared_prefix > 0
                ):
                    sess.kv_committed = self.kv_shared_prefix
            self.links[session] = (uplink, downlink)
            self.sessions[session] = sess
        self._threads.append(
            self.clock.spawn(lambda: self._rx_loop(session), name=f"rx-{session}")
        )

    def drain(self) -> None:
        """Stop admitting new sessions; existing sessions keep serving."""
        with self._lock:
            self.draining = True

    def start(self) -> None:
        """Start the dispatch loop (receive loops start per ``attach``)."""
        self._threads.append(self.clock.spawn(self._dispatch_loop, name="dispatch"))

    def stop(self) -> None:
        """Close uplinks and drain in-flight dispatch before returning."""
        self._stop.set()
        with self._work:
            self._work.notify_all()
        for s, (up, dn) in list(self.links.items()):
            up.close()
        for t in self._threads:  # drain in-flight dispatch before reporting
            t.join(timeout=5.0)

    def load_summary(self) -> dict:
        """Occupancy/queue-depth/KV-residency view for benchmarks (→ RunStats)."""
        out = dict(
            batch_occupancy=self.monitor.verifier_occupancy() or 0.0,
            mean_queue_depth=self.monitor.verifier_queue_depth() or 0.0,
            verifier_batches=list(self.monitor.verifier_batches()),
            verifier_queue_depths=list(self.monitor.verifier_depths()),
            # Results delivered but not yet consumed by edge clients.
            dn_backlog=sum(dn.qsize() for (_, dn) in self.links.values()),
            **self.stats,
        )
        if self.kv_pool is not None:
            out.update(self.kv_pool.load_summary())
            out["kv_bytes_series"] = self.monitor.kv_bytes_series()
            out["kv_sessions_series"] = self.monitor.kv_sessions_series()
        return out

    def telemetry_snapshot(self, seq: int = 0, session: int = -1) -> TelemetrySnapshot:
        """Point-in-time :class:`TelemetrySnapshot` of this verifier.

        The typed reply to a :class:`TelemetryRequest` (and the building
        block the router aggregates fleet-wide).  Fixed fields carry the
        serving hot metrics; the ``names``/``values`` lanes carry the
        long-tail counters (drops, parking, backlog) without protocol churn.
        """
        with self._lock:
            queue_depth = len(self._queue)
            sessions_active = len(self.sessions)
            dn_backlog = sum(dn.qsize() for (_, dn) in self.links.values())
            extras = [
                ("dn_backlog", float(dn_backlog)),
                ("dropped_dead_sessions", float(self.stats["dropped_dead_sessions"])),
                ("dropped_stragglers", float(self.stats["dropped_stragglers"])),
                ("kv_parked", float(self.stats["kv_parked"])),
                ("max_queue_depth", float(self.stats["max_queue_depth"])),
            ]
            kv = dict(
                kv_used_blocks=0, kv_free_blocks=0, kv_resident_bytes=0,
                kv_resident_sessions=0,
            )
            if self.kv_pool is not None:
                kv = dict(
                    kv_used_blocks=self.kv_pool.used_blocks,
                    kv_free_blocks=self.kv_pool.free_blocks,
                    kv_resident_bytes=self.kv_pool.resident_bytes(),
                    kv_resident_sessions=self.kv_pool.resident_sessions,
                )
            return TelemetrySnapshot(
                session=session,
                seq=seq,
                verifier=self.verifier_id,
                n_verifiers=1,
                t=self.clock.monotonic(),
                sessions_active=sessions_active,
                queue_depth=queue_depth,
                nav_calls=self.stats["nav_calls"],
                tokens_verified=self.stats["tokens_verified"],
                accepted_tokens=self.stats["accepted_tokens"],
                batched_calls=self.stats["batched_calls"],
                occupancy=self.monitor.verifier_occupancy() or 0.0,
                verify_busy_time=self.stats["verify_busy_time"],
                kv_cap_hits=self.stats["kv_cap_hits"],
                names=tuple(k for k, _ in extras),
                values=tuple(v for _, v in extras),
                **kv,
            )

    # ------------------------------------------------------------ receive --
    def _enqueue_round(self, session: int, sess: _Session, msg: NavRequest) -> None:
        """Pop the round's tokens off its buffer and queue the request.

        Caller holds ``self._lock``.
        """
        n = msg.n_tokens
        rnd = msg.round
        toks, confs, pars = sess.buf(rnd)
        take_t, take_c, take_p = toks[:n], confs[:n], pars[:n]
        rest = (toks[n:], confs[n:], pars[n:])
        if rest[0]:
            # Collapse the leftover into one tail fragment at the round's
            # highest seq, keeping the seq-ordered reassembly invariant.
            sess.buffers[rnd] = {max(sess.buffers[rnd]): rest}
        else:
            sess.buffers.pop(rnd, None)
            sess.buf_seqs.pop(rnd, None)
        sess.max_round_enqueued = max(sess.max_round_enqueued, rnd)
        self._queue.append(
            _VerifyRequest(
                session,
                take_t,
                take_c,
                msg,
                self.clock.monotonic(),
                msg.deadline,
                parents=take_p if isinstance(msg, TreeNavRequest) else None,
                pos=msg.pos,
                epoch=sess.epoch,
            )
        )
        self._work.notify_all()

    def _rx_loop(self, session: int) -> None:
        up, dn = self.links[session]
        while not self._stop.is_set():
            msg = up.recv(timeout=0.25)
            if msg is None:
                if getattr(up, "closed", False):
                    # The link is permanently gone (socket EOF / channel
                    # close): end the receive loop instead of hot-polling a
                    # dead transport.  Dispatch-side session cleanup still
                    # runs through the session-timeout path.
                    return
                continue
            with self._lock:
                sess = self.sessions.get(session)
                if sess is None or self.links.get(session, (None,))[0] is not up:
                    # Detached, or superseded by a re-attach: late messages
                    # on the old link must not touch the new session's state.
                    return
            sess.last_seen = self.clock.monotonic()
            if isinstance(msg, Drain):
                self.drain()
                continue
            if isinstance(msg, DraftFragment):
                rnd = msg.round
                with self._lock:
                    # A retransmitted (duplicated) fragment must not extend the
                    # round buffer twice — dedupe on the message seq; the
                    # fragment map keys on seq so reorder-delayed fragments
                    # reassemble into the client's draft order.
                    seen = sess.buf_seqs.setdefault(rnd, set())
                    if msg.seq in seen:
                        continue
                    seen.add(msg.seq)
                    sess.buffers.setdefault(rnd, {})[msg.seq] = (
                        list(msg.tokens),
                        list(msg.confs),
                        list(msg.parents),
                    )
                    # A parked NAV round becomes dispatchable the moment its
                    # proactively-uploaded drafts complete the buffer.
                    pend = sess.pending_request
                    if (
                        pend is not None
                        and pend.round == rnd
                        and len(sess.buf(rnd)[0]) >= pend.n_tokens
                    ):
                        sess.pending_request = None
                        self._enqueue_round(session, sess, pend)
            elif isinstance(msg, NavRequest):  # chain and tree alike
                rnd = msg.round
                with self._lock:
                    # A duplicated NavRequest for an already-enqueued round
                    # must not verify (and KV-commit) the round twice, and a
                    # stale (reorder-delayed) request from a round the client
                    # has since abandoned must not displace a newer parked
                    # round.
                    pend = sess.pending_request
                    pend_rnd = pend.round if pend is not None else 0
                    if 0 < rnd and (rnd <= sess.max_round_enqueued or rnd < pend_rnd):
                        continue
                    # Abandoned earlier rounds (failover on the client) can
                    # never be requested again — drop their buffers, and any
                    # still-parked older request, without touching this round.
                    for stale in [r for r in sess.buffers if r < rnd]:
                        del sess.buffers[stale]
                        sess.buf_seqs.pop(stale, None)
                    if sess.pending_request is not None and sess.pending_request.round < rnd:
                        sess.pending_request = None
                    if len(sess.buf(rnd)[0]) >= msg.n_tokens:
                        self._enqueue_round(session, sess, msg)
                    else:
                        sess.pending_request = msg
            elif isinstance(msg, Reset):
                with self._lock:
                    sess.buffers.clear()
                    sess.buf_seqs.clear()
                    sess.pending_request = None
                    self._kv_reconcile(session, sess, msg.position)
            elif isinstance(msg, TelemetryRequest):
                # Telemetry poll on a session link: reply with this
                # verifier's snapshot (the router intercepts requests on
                # routed sessions and answers fleet-wide instead).
                dn.send(self.telemetry_snapshot(seq=msg.seq, session=msg.session))
            elif isinstance(msg, Hello):
                # In-band attach (socket clients handshake at the listener;
                # an in-process Hello still gets a well-formed reply).
                dn.send(handshake_reply(msg, session=session))
            elif isinstance(msg, Detach):
                # The client is done: drop buffered rounds, return the
                # session's KV pages to the pool, deregister the session, and
                # end the receive loop.  (Migration sends this on the OLD
                # verifier so its placement slot frees immediately.)
                with self._lock:
                    if self.sessions.get(session) is not sess:
                        return  # superseded mid-handling; nothing to clean
                    sess.buffers.clear()
                    sess.buf_seqs.clear()
                    sess.pending_request = None
                    if self.kv_pool is not None and session in self.kv_pool.tables:
                        self.kv_pool.release(session)
                    del self.sessions[session]
                    self.links.pop(session, None)
                return
            # Heartbeat (and anything unrecognized): last_seen was refreshed.

    # ----------------------------------------------------------- dispatch --
    def _kv_reconcile(self, session: int, sess: _Session, position: int) -> None:
        """Re-attach reconciliation: adopt the edge's committed stream length.

        After an offline spell the edge's position is authoritative — it kept
        decoding locally.  The verifier's logical cache length moves to the
        edge position; cloud-side pages past it (rounds verified whose
        results the edge never received) roll back to the fork, and the
        re-prefill gap (tokens the edge decoded offline) is appended by the
        next dispatch's ``_kv_secure`` exactly like a post-eviction comeback
        — replaying the paged-KV fork on the cloud side.  Caller holds
        ``self._lock``.
        """
        base = (
            self.kv_shared_prefix
            if (self.kv_pool is not None and self.kv_flat_reserve is None)
            else 0
        )
        sess.epoch += 1  # rounds still in flight were abandoned by the edge
        sess.kv_committed = base + max(position, 0)
        if self.kv_pool is not None and session in self.kv_pool.tables:
            keep = min(self.kv_pool.length(session), sess.kv_committed)
            self.kv_pool.rollback(session, keep)

    def _kv_register(self, session: int) -> None:
        """Give a session its pool table per the configured KV policy.

        Flat mode creates + reserves up front (``BlockPoolExhausted``
        propagates — the flat admission limit — with the half-made table
        cleaned up); shared-prefix mode forks the prefix owner CoW; plain
        paged mode starts empty.  Used at ``attach`` and when a
        timed-out-then-resumed session needs its released table back.
        Caller holds ``self._lock``.
        """
        if self.kv_flat_reserve is not None:
            self.kv_pool.create(session)
            try:
                self.kv_pool.reserve(session, self.kv_flat_reserve)
            except BlockPoolExhausted:
                self.kv_pool.release(session)
                raise
        elif self.kv_shared_prefix > 0:
            self.kv_pool.fork(self.KV_PREFIX_SESSION, session)
        else:
            self.kv_pool.create(session)

    def _kv_secure(self, req: _VerifyRequest, active: set) -> bool:
        """Back a round's KV growth with pool pages (caller holds the lock).

        The round writes ``K+1`` cache positions past the session's committed
        prefix (plus any re-prefill gap if the session was evicted).  Paged
        sessions that cannot be backed first reclaim pages from the
        least-recently-active idle session, then report failure (the caller
        parks the request).  Flat reservations never block — they saturate at
        their fixed capacity (``kv_cap_hits``), exactly like a flat cache
        sized at ``max_len``.

        A session whose table was released as dead (timeout) but that later
        resumed is re-registered here — re-forking the shared prefix (paged)
        or re-reserving (flat; parks while the budget is full) — so a
        comeback never serves outside the pool's admission control.
        """
        pool = self.kv_pool
        if pool is None:
            return True
        if req.session not in pool.tables:
            try:
                self._kv_register(req.session)
            except BlockPoolExhausted:
                return False  # comeback parks until the budget has room
        sess = self.sessions[req.session]
        need = sess.kv_committed - pool.length(req.session) + len(req.tokens) + 1
        if need <= 0:
            req.kv_secured = True
            return True
        table = pool.tables[req.session]
        if table.reserved:
            room = table.capacity(pool.block_size) - pool.length(req.session)
            if need > room:
                self.stats["kv_cap_hits"] += 1
                need = room
            if need > 0:
                pool.append(req.session, need)
            req.kv_secured = True
            return True
        while not pool.can_append(req.session, need):
            if pool.evict_lru(exclude=active) is None:
                return False
        pool.append(req.session, need)
        req.kv_secured = True
        return True

    def _admit(self) -> Tuple[List[_VerifyRequest], int]:
        """Admission control under ``self._lock``: drop dead work, pick fairly.

        Returns (admitted batch, queue depth at admission time).  Requests
        beyond ``max_batch`` are *reinserted* at the head in arrival order,
        so nothing is lost — but admission order is (served-rounds, arrival),
        which keeps chatty long-draft sessions from starving short ones.
        With a KV pool, admission is additionally gated on the free-block
        budget: a request whose cache growth cannot be backed (even after
        LRU eviction of idle sessions) parks back at the queue head.
        """
        now = self.clock.monotonic()
        live: List[_VerifyRequest] = []
        for req in self._drain_queue():
            if self.drop_expired and req.deadline is not None and now > req.deadline:
                self.stats["dropped_stragglers"] += 1  # client already failed over
                continue
            sess = self.sessions.get(req.session)
            if sess is None or now - sess.last_seen > self.session_timeout:
                self.stats["dropped_dead_sessions"] += 1
                if self.kv_pool is not None and req.session in self.kv_pool.tables:
                    self.kv_pool.release(req.session)  # reclaim a dead cache
                continue
            live.append(req)
        depth = len(live)
        self.stats["max_queue_depth"] = max(self.stats["max_queue_depth"], depth)
        if depth <= self.max_batch:
            admitted, overflow = live, []
        else:
            order = sorted(
                range(depth),
                key=lambda i: (self.sessions[live[i].session].served, live[i].t_enqueue),
            )
            take = set(order[: self.max_batch])
            admitted = [live[i] for i in sorted(take)]
            overflow = [live[i] for i in range(depth) if i not in take]
        if self.kv_pool is not None and admitted:
            # Sessions with in-flight or queued work must keep their pages:
            # evicting them would desync committed lengths mid-round.
            active = {r.session for r in live} | {self.KV_PREFIX_SESSION}
            active.update(s for s, sess in self.sessions.items() if sess.pending_request)
            secured = []
            for req in admitted:
                if self._kv_secure(req, active):
                    secured.append(req)
                else:
                    self.stats["kv_parked"] += 1  # retried next dispatch round
                    overflow.insert(0, req)
            admitted = secured
        for req in reversed(overflow):
            self._queue.appendleft(req)  # fair reinsertion, arrival order kept
        return admitted, depth

    def _drain_queue(self) -> List[_VerifyRequest]:
        drained = list(self._queue)
        self._queue.clear()
        return drained

    def _dispatch_loop(self) -> None:
        while not self._stop.is_set():
            with self._work:
                while not self._queue and not self._stop.is_set():
                    self._work.wait(timeout=0.25)
                if self._stop.is_set():
                    return
            if self.batch_window > 0:
                with self._lock:
                    full = len(self._queue) >= self.max_batch
                if not full:  # a full batch needs no coalescing delay
                    self.clock.sleep(self.batch_window)  # absorb concurrent arrivals
            with self._lock:
                batch, depth = self._admit()
            if not batch:
                # Nothing admitted but work may remain queued (all requests
                # KV-parked): back off instead of hot-spinning until pages
                # free up, a deadline expires, or new work arrives.
                with self._work:
                    if self._queue and not self._stop.is_set():
                        self._work.wait(timeout=0.05)
                continue
            # Chain and tree requests share the admission queue but pad
            # differently (draft length vs node count), so each kind gets its
            # own backend launch within ONE dispatch round.
            chain = [r for r in batch if r.parents is None]
            tree = [r for r in batch if r.parents is not None]
            results: Dict[int, tuple] = {}
            verify_t0 = self.clock.monotonic()
            if chain:
                if self.backend.positional:
                    # Positional backends (runtime.oracle) verify statelessly
                    # against the stream position carried by the NAV request.
                    out = self.backend.verify_batch_pos(
                        [(r.session, r.tokens, r.confs, r.pos) for r in chain]
                    )
                else:
                    out = self.backend.verify_batch(
                        [(r.session, r.tokens, r.confs) for r in chain]
                    )
                for r, (n_acc, corr) in zip(chain, out):
                    results[id(r)] = (n_acc, corr, None)
            if tree:
                out = self.backend.verify_tree_batch(
                    [(r.session, r.tokens, r.confs, r.parents) for r in tree]
                )
                for r, (n_acc, corr, path) in zip(tree, out):
                    results[id(r)] = (n_acc, corr, path)
            verify_t1 = self.clock.monotonic()
            self.stats["verify_busy_time"] += verify_t1 - verify_t0
            self.stats["nav_calls"] += len(batch)
            self.stats["batched_calls"] += 1
            self.monitor.observe_verifier_batch(len(batch), depth)
            if self.tracer.enabled:
                # One verify span per dispatch; one nav_queue span per
                # admitted request covering enqueue → backend start.
                self.tracer.add(
                    "verify", verify_t0, verify_t1,
                    verifier=self.verifier_id, batch=len(batch), depth=depth,
                )
                for req in batch:
                    self.tracer.add(
                        "nav_queue", req.t_enqueue, verify_t0,
                        session=req.session, round=req.msg.round,
                        verifier=self.verifier_id,
                    )
            if self.metrics is not None:
                self.metrics.counter(
                    "verifier_nav_calls", "NAV requests verified"
                ).inc(len(batch), verifier=self.verifier_id)
                self.metrics.histogram(
                    "verifier_batch_size", "Admitted NAV batch sizes"
                ).observe(len(batch), verifier=self.verifier_id)
                self.metrics.gauge(
                    "verifier_queue_depth", "Queue depth at admission"
                ).set(depth, verifier=self.verifier_id)
            for req in batch:
                n_acc, corr, path = results[id(req)]
                self.stats["tokens_verified"] += len(req.tokens)
                self.stats["accepted_tokens"] += n_acc
                sess = self.sessions.get(req.session)
                if sess is not None:
                    sess.served += 1
                    # Commit accepted + correction tokens; with a pool, also
                    # release every page wholly past the new prefix (rejection
                    # rollback is a page free, not a buffer copy).  A round
                    # verified across a re-attach reconciliation (stale epoch)
                    # was abandoned by the edge: committing it would inflate
                    # the reconciled position, so it is dropped here (the
                    # client discards its stale result by seq anyway).
                    with self._lock:
                        if req.epoch == sess.epoch:
                            sess.kv_committed += n_acc + 1
                            if (
                                req.kv_secured
                                and self.kv_pool is not None
                                and req.session in self.kv_pool.tables
                            ):
                                self.kv_pool.rollback(
                                    req.session,
                                    min(sess.kv_committed, self.kv_pool.length(req.session)),
                                )
                link = self.links.get(req.session)
                if link is None:
                    continue
                _, dn = link
                dn.send(
                    NavResult(
                        session=req.session,
                        seq=req.msg.seq,
                        n_accepted=n_acc,
                        correction=corr,
                        n_drafted=len(req.tokens),
                        # Chain rounds carry no path; tree rounds carry the
                        # accepted packed node indices (possibly empty).
                        path=tuple(path) if path is not None else None,
                    )
                )
            if self.kv_pool is not None:
                with self._lock:
                    self.monitor.observe_kv(
                        self.kv_pool.resident_bytes(), self.kv_pool.resident_sessions
                    )
