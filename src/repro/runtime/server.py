"""Cloud verifier service (the paper's FastAPI server, §4.2, App. I).

One dispatcher thread serves any number of edge sessions:
* buffers draft tokens per session as batches stream in (pipelined upload);
* on a NAV request (or when a session's buffered proactive tokens satisfy a
  pending round) runs the verification backend;
* supports *batched NAV*: requests that arrive within ``batch_window`` are
  verified in one backend call (beyond-paper optimization #5 — amortizes the
  target forward across clients);
* straggler mitigation: requests carry deadlines; the server drops work for
  sessions that disconnected.

The backend is pluggable: ``SyntheticBackend`` (trace-driven acceptance, used
by benchmarks) or a real JAX verify_step (examples/cloud_edge_serve.py).
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from .transport import Channel, Message

__all__ = ["VerifyBackend", "SyntheticBackend", "CloudVerifier"]


class VerifyBackend:
    """Interface: verify a session's drafted tokens → (n_accepted, correction)."""

    def verify(self, session: int, tokens: List[int], confs: List[float]):  # pragma: no cover
        raise NotImplementedError

    def verify_batch(self, requests):
        return [self.verify(s, t, c) for (s, t, c) in requests]


@dataclass
class SyntheticBackend(VerifyBackend):
    """Acceptance ~ conf^kappa per token (matches core.pipeline.SyntheticSource)."""

    kappa: float = 0.8
    seed: int = 0
    verify_time: float = 0.080  # simulated target forward time [s]
    verify_time_per_token: float = 0.004
    time_scale: float = 1.0

    def __post_init__(self) -> None:
        self._rng = np.random.default_rng(self.seed)

    def verify(self, session: int, tokens: List[int], confs: List[float]):
        time.sleep((self.verify_time + self.verify_time_per_token * len(tokens)) * self.time_scale)
        n_acc = 0
        for c in confs:
            if self._rng.random() < c**self.kappa:
                n_acc += 1
            else:
                break
        correction = int(self._rng.integers(0, 1 << 16))
        return n_acc, correction


@dataclass
class _Session:
    tokens: List[int] = field(default_factory=list)
    confs: List[float] = field(default_factory=list)
    pending_request: Optional[Message] = None
    last_seen: float = field(default_factory=time.monotonic)


class CloudVerifier:
    """Dispatcher thread over (uplink, downlink) channel pairs per session."""

    def __init__(
        self,
        backend: VerifyBackend,
        batch_window: float = 0.0,  # >0 → batch concurrent NAV requests
        session_timeout: float = 30.0,
    ):
        self.backend = backend
        self.batch_window = batch_window
        self.session_timeout = session_timeout
        self.links: Dict[int, tuple] = {}  # session -> (uplink, downlink)
        self.sessions: Dict[int, _Session] = {}
        self.stats = {"nav_calls": 0, "tokens_verified": 0, "batched_calls": 0}
        self._stop = threading.Event()
        self._threads: List[threading.Thread] = []
        self._lock = threading.Lock()
        self._ready: List[tuple] = []  # (session, tokens, confs, request msg)

    def attach(self, session: int, uplink: Channel, downlink: Channel) -> None:
        with self._lock:
            self.links[session] = (uplink, downlink)
            self.sessions[session] = _Session()
        t = threading.Thread(target=self._rx_loop, args=(session,), daemon=True)
        t.start()
        self._threads.append(t)

    def start(self) -> None:
        t = threading.Thread(target=self._dispatch_loop, daemon=True)
        t.start()
        self._threads.append(t)

    def stop(self) -> None:
        self._stop.set()
        for s, (up, dn) in self.links.items():
            up.close()

    # ------------------------------------------------------------ receive --
    def _rx_loop(self, session: int) -> None:
        up, dn = self.links[session]
        while not self._stop.is_set():
            msg = up.recv(timeout=0.25)
            if msg is None:
                continue
            sess = self.sessions[session]
            sess.last_seen = time.monotonic()
            if msg.kind == "draft_batch":
                tokens, confs = msg.payload
                sess.tokens.extend(tokens)
                sess.confs.extend(confs)
            elif msg.kind == "nav_request":
                with self._lock:
                    n = msg.payload["n_tokens"]
                    take_t, take_c = sess.tokens[:n], sess.confs[:n]
                    sess.tokens, sess.confs = sess.tokens[n:], sess.confs[n:]
                    self._ready.append((session, take_t, take_c, msg))
            elif msg.kind == "reset":
                sess.tokens.clear()
                sess.confs.clear()

    # ----------------------------------------------------------- dispatch --
    def _dispatch_loop(self) -> None:
        while not self._stop.is_set():
            with self._lock:
                batch, self._ready = self._ready, []
            if not batch:
                time.sleep(0.002)
                continue
            if self.batch_window > 0:
                time.sleep(self.batch_window)  # absorb concurrent arrivals
                with self._lock:
                    batch += self._ready
                    self._ready = []
            reqs = [(s, t, c) for (s, t, c, _) in batch]
            results = self.backend.verify_batch(reqs)
            self.stats["nav_calls"] += len(batch)
            self.stats["batched_calls"] += 1
            for (session, tokens, confs, msg), (n_acc, corr) in zip(batch, results):
                self.stats["tokens_verified"] += len(tokens)
                _, dn = self.links[session]
                dn.send(
                    Message(
                        "nav_result",
                        session,
                        msg.seq,
                        max(n_acc, 1),
                        {"n_accepted": n_acc, "correction": corr, "n_drafted": len(tokens)},
                    )
                )
