"""Optimizers: AdamW and Adafactor (factored second moment).

Minimal optax-style (init/update) pure-function optimizers.  Adafactor is the
default for ≥100B-parameter configs (DESIGN.md §5): its factored second
moment keeps optimizer state ≈ O(rows+cols) per matrix so arctic-480b's
train_4k cell fits v5e HBM where AdamW's fp32 m/v would not.
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Schedule = Callable[[jax.Array], jax.Array]


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jax.Array], Tuple[Any, Any]]
    # update(grads, state, params, step) -> (updates, new_state)


def apply_updates(params: Any, updates: Any) -> Any:
    return jax.tree_util.tree_map(lambda p, u: (p + u).astype(p.dtype), params, updates)


def clip_by_global_norm(grads: Any, max_norm: float) -> Tuple[Any, jax.Array]:
    leaves = jax.tree_util.tree_leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), gnorm


# ------------------------------------------------------------------- AdamW --


def adamw(
    lr: Schedule | float,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: jnp.float32(lr))

    def init(params):
        z = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        return {
            "m": jax.tree_util.tree_map(z, params),
            "v": jax.tree_util.tree_map(z, params),
        }

    def update(grads, state, params, step):
        stepf = step.astype(jnp.float32) + 1.0
        lr_t = lr_fn(step)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m2 = b1 * m + (1 - b1) * g
            v2 = b2 * v + (1 - b2) * g * g
            mhat = m2 / (1 - b1**stepf)
            vhat = v2 / (1 - b2**stepf)
            u = -lr_t * (mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32))
            return u, m2, v2

        out = jax.tree_util.tree_map(upd, grads, state["m"], state["v"], params)
        updates = jax.tree_util.tree_map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
        new_m = jax.tree_util.tree_map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
        new_v = jax.tree_util.tree_map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
        return updates, {"m": new_m, "v": new_v}

    return Optimizer(init, update)


# --------------------------------------------------------------- Adafactor --


def adafactor(
    lr: Schedule | float,
    decay: float = 0.8,
    eps: float = 1e-30,
    clip_threshold: float = 1.0,
    weight_decay: float = 0.0,
) -> Optimizer:
    """Factored second-moment optimizer (Shazeer & Stern 2018), no momentum.

    Matrices (rank ≥ 2) store row/col second-moment vectors over the last two
    dims; vectors store the full second moment.  State is ~O(N/min(r,c)).
    """
    lr_fn = lr if callable(lr) else (lambda _: jnp.float32(lr))

    def _factored(p) -> bool:
        return p.ndim >= 2

    def init(params):
        def per(p):
            if _factored(p):
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),  # row stats
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),  # col stats
                }
            return {"v": jnp.zeros_like(p, dtype=jnp.float32)}

        return jax.tree_util.tree_map(per, params, is_leaf=lambda x: isinstance(x, jax.Array) or hasattr(x, "shape"))

    def update(grads, state, params, step):
        stepf = step.astype(jnp.float32) + 1.0
        beta = 1.0 - stepf ** (-decay)
        lr_t = lr_fn(step)

        def _factored_update(g, st, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            vr = beta * st["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
            vc = beta * st["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
            rfac = vr / jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), eps)
            denom = jnp.sqrt(rfac[..., None] * vc[..., None, :])
            u = g / jnp.maximum(denom, eps)
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-30)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            u = (-lr_t * u).astype(p.dtype)
            if weight_decay:
                u = u - (lr_t * weight_decay) * p
            return u, {"vr": vr, "vc": vc}

        def per(g, st, p):
            if _factored(p):
                # NOTE (§Perf arctic/it3, refuted): lax.map over the layer dim
                # was tried to shrink full-leaf f32 optimizer temps; the map's
                # stacked output + double buffering measured *worse*
                # (48.4 → 51.9 GiB/device). Direct update stands.
                return _factored_update(g, st, p)
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            v = beta * st["v"] + (1 - beta) * g2
            u = g / jnp.sqrt(jnp.maximum(v, eps))
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-30)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            u = -lr_t * u
            if weight_decay:
                u = u - lr_t * weight_decay * p.astype(jnp.float32)
            return u, {"v": v}

        flat, treedef = jax.tree_util.tree_flatten(params)
        gflat = treedef.flatten_up_to(grads)
        sflat = treedef.flatten_up_to(state)
        out = [per(g, s, p) for g, s, p in zip(gflat, sflat, flat)]
        updates = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
        new_state = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
        return updates, new_state

    return Optimizer(init, update)
