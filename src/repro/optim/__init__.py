from .optimizers import Optimizer, adafactor, adamw, apply_updates, clip_by_global_norm
from .schedules import constant, cosine_schedule, wsd_schedule
from .compression import (
    ErrorFeedbackState,
    compress_int8,
    compressed_gradient_transform,
    decompress_int8,
    init_error_feedback,
)

__all__ = [
    "Optimizer",
    "adafactor",
    "adamw",
    "apply_updates",
    "clip_by_global_norm",
    "constant",
    "cosine_schedule",
    "wsd_schedule",
    "compress_int8",
    "decompress_int8",
    "ErrorFeedbackState",
    "compressed_gradient_transform",
    "init_error_feedback",
]
