"""LR schedules: constant, cosine, and WSD (warmup-stable-decay, MiniCPM)."""

from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.float32(lr)


def cosine_schedule(peak_lr: float, warmup_steps: int, total_steps: int, final_frac: float = 0.1):
    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * jnp.minimum(step / jnp.maximum(warmup_steps, 1), 1.0)
        t = jnp.clip((step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup_steps, warm, peak_lr * cos)

    return fn


def wsd_schedule(peak_lr: float, warmup_steps: int, stable_steps: int, decay_steps: int, final_frac: float = 0.01):
    """MiniCPM's Warmup-Stable-Decay: linear warmup → flat → exp-style decay."""

    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * jnp.minimum(step / jnp.maximum(warmup_steps, 1), 1.0)
        d_t = jnp.clip((step - warmup_steps - stable_steps) / jnp.maximum(decay_steps, 1), 0.0, 1.0)
        decay = peak_lr * (final_frac ** d_t)
        out = jnp.where(step < warmup_steps, warm, jnp.where(step < warmup_steps + stable_steps, peak_lr, decay))
        return out

    return fn
