"""Int8 gradient compression with error feedback (distributed-training trick).

Reduces data-parallel all-reduce volume 4× (fp32→int8) at equal convergence
via error feedback: the quantization residual is carried into the next step's
gradient.  Used as an optional transform around the optimizer in
``launch/train.py`` (``--grad-compression int8``); under SPMD the quantized
gradients are what crosses the ``data``/``pod`` axes.
"""

from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class ErrorFeedbackState(NamedTuple):
    residual: Any  # pytree of fp32 residuals


def compress_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization. Returns (q, scale)."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_gradient_transform(grads: Any, ef: ErrorFeedbackState) -> Tuple[Any, ErrorFeedbackState]:
    """Quantize (grad + residual) to int8; new residual = quantization error."""

    def per(g, r):
        g32 = g.astype(jnp.float32) + r
        q, s = compress_int8(g32)
        deq = decompress_int8(q, s)
        return deq, g32 - deq

    out = jax.tree_util.tree_map(per, grads, ef.residual)
    deq = jax.tree_util.tree_map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    res = jax.tree_util.tree_map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    return deq, ErrorFeedbackState(res)


def init_error_feedback(params: Any) -> ErrorFeedbackState:
    return ErrorFeedbackState(jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params))
