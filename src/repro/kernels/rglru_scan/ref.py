"""Pure-jnp oracle for the RG-LRU linear scan (associative_scan based)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rglru_scan_ref(a: jax.Array, b: jax.Array, h0: jax.Array) -> jax.Array:
    """h_t = a_t·h_{t-1} + b_t along axis 1; h_0 given. Returns all h_t."""
    b = b.at[:, 0, :].add(a[:, 0, :] * h0)

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h
