"""Pallas TPU chunked RG-LRU linear-recurrence scan.

Computes h_t = a_t ⊙ h_{t-1} + b_t over long sequences.  Grid:
(batch, channel_blocks, time_blocks) with the time dimension "arbitrary":
the hidden state (one [BD] vector) persists in VMEM scratch across time
blocks, and each block runs a short sequential ``fori_loop`` over its BT
steps entirely in VMEM — HBM traffic is exactly one read of (a, b) and one
write of h (the memory-bound optimum for this op).

This is the TPU adaptation of the paper-family's CUDA linear-scan kernels:
instead of warp-level scans, VMEM residency + the 8×128 VPU lanes do the
work; the sequential dependency only crosses time *blocks*, not HBM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .._compat import CompilerParams

DEFAULT_BT = 256
DEFAULT_BD = 512


def _rglru_kernel(
    a_ref,  # [1, BT, BD]
    b_ref,  # [1, BT, BD]
    h0_ref,  # [1, BD]
    o_ref,  # [1, BT, BD]
    h_scr,  # [BD] f32 carried hidden state
    *,
    bt: int,
):
    tb = pl.program_id(2)

    @pl.when(tb == 0)
    def _init():
        h_scr[...] = h0_ref[0].astype(jnp.float32)

    a = a_ref[0].astype(jnp.float32)
    b = b_ref[0].astype(jnp.float32)

    def step(t, h):
        h = a[t] * h + b[t]
        o_ref[0, t, :] = h.astype(o_ref.dtype)
        return h

    h_scr[...] = jax.lax.fori_loop(0, bt, step, h_scr[...])


def rglru_scan_pallas(
    a: jax.Array,  # [B, T, D]
    b: jax.Array,  # [B, T, D]
    h0: jax.Array,  # [B, D]
    *,
    block_t: int = DEFAULT_BT,
    block_d: int = DEFAULT_BD,
    interpret: bool = False,
) -> jax.Array:
    B, T, D = a.shape
    bt = min(block_t, T)
    bd = min(block_d, D)
    if T % bt or D % bd:
        raise ValueError(f"(T={T}, D={D}) must divide into blocks ({bt},{bd})")
    nt, nd = T // bt, D // bd
    kernel = functools.partial(_rglru_kernel, bt=bt)
    return pl.pallas_call(
        kernel,
        grid=(B, nd, nt),
        in_specs=[
            pl.BlockSpec((1, bt, bd), lambda i, d, t: (i, t, d)),
            pl.BlockSpec((1, bt, bd), lambda i, d, t: (i, t, d)),
            pl.BlockSpec((1, bd), lambda i, d, t: (i, d)),
        ],
        out_specs=pl.BlockSpec((1, bt, bd), lambda i, d, t: (i, t, d)),
        out_shape=jax.ShapeDtypeStruct((B, T, D), a.dtype),
        scratch_shapes=[pltpu.VMEM((bd,), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(a, b, h0)
