"""Jit'd wrapper for the RG-LRU scan kernel."""

from __future__ import annotations

import functools

import jax

from .kernel import rglru_scan_pallas
from .ref import rglru_scan_ref


@functools.partial(jax.jit, static_argnames=("impl", "block_t", "block_d"))
def rglru_scan(
    a: jax.Array,  # [B, T, D]
    b: jax.Array,
    h0: jax.Array,  # [B, D]
    *,
    impl: str = "interpret",
    block_t: int = 256,
    block_d: int = 512,
) -> jax.Array:
    if impl == "ref":
        return rglru_scan_ref(a, b, h0)
    return rglru_scan_pallas(a, b, h0, block_t=block_t, block_d=block_d, interpret=(impl == "interpret"))
