from . import ops, ref
from .kernel import rglru_scan_pallas
from .ops import rglru_scan
from .ref import rglru_scan_ref

__all__ = ["rglru_scan", "rglru_scan_pallas", "rglru_scan_ref", "ops", "ref"]
