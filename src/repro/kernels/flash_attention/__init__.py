from . import ops, ref
from .kernel import flash_attention_pallas
from .ops import flash_attention
from .ref import flash_attention_ref

__all__ = ["flash_attention", "flash_attention_pallas", "flash_attention_ref", "ops", "ref"]
