"""Pure-jnp oracle for flash attention (causal + window + softcap + GQA)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(
    q: jax.Array,  # [B, T, H, hd]
    k: jax.Array,  # [B, T, H, hd]
    v: jax.Array,
    *,
    window: int = 1 << 30,
    softcap: float = 0.0,
    causal: bool = True,
) -> jax.Array:
    B, T, H, hd = q.shape
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s / math.sqrt(hd)
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    qp = jnp.arange(T)[:, None]
    kp = jnp.arange(T)[None, :]
    dist = qp - kp
    mask = dist < window
    if causal:
        mask = jnp.logical_and(mask, dist >= 0)
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32)).astype(q.dtype)
