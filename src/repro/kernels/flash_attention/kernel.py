"""Pallas TPU flash attention (prefill): causal, GQA, sliding-window, softcap.

Grid layout: (batch·q_heads, num_q_blocks, num_k_blocks) with dimension
semantics ("parallel", "parallel", "arbitrary") — the k dimension iterates
sequentially per (bh, q-block) so the online-softmax running state (m, l,
acc) lives in VMEM scratch across k iterations and is finalized on the last
k block.

BlockSpecs tile Q/K/V into VMEM: q [1, BQ, hd], k/v [1, BK, hd]; the working
set per step is BQ·hd + 2·BK·hd + BQ·BK floats — with BQ=BK=128 and
hd≤256 this is ≤ ~0.4 MB, far under the ~16 MB v5e VMEM budget, and all
matmul dims are 128-aligned for the MXU.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .._compat import CompilerParams

DEFAULT_BQ = 128
DEFAULT_BK = 128
NEG_INF = -1e30


def _flash_kernel(
    q_ref,  # [1, BQ, hd]
    k_ref,  # [1, BK, hd]
    v_ref,  # [1, BK, hd]
    o_ref,  # [1, BQ, hd]
    m_scr,  # [BQ] f32 scratch — running max
    l_scr,  # [BQ] f32 scratch — running denom
    acc_scr,  # [BQ, hd] f32 scratch — running numerator
    *,
    sm_scale: float,
    window: int,
    softcap: float,
    bq: int,
    bk: int,
    nk: int,
    causal: bool,
):
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)  # [BQ, hd]
    k = k_ref[0].astype(jnp.float32)  # [BK, hd]
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * sm_scale  # [BQ, BK]
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    q_pos = pl.program_id(1) * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = kb * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    dist = q_pos - k_pos
    mask = dist < window
    if causal:
        mask = jnp.logical_and(mask, dist >= 0)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_scr[...] + jnp.sum(p, axis=-1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot(p, v)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(kb == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,  # [B, T, H, hd]
    k: jax.Array,  # [B, T, H, hd]  (GQA-expanded by the wrapper)
    v: jax.Array,
    *,
    window: int = 1 << 30,
    softcap: float = 0.0,
    causal: bool = True,
    block_q: int = DEFAULT_BQ,
    block_k: int = DEFAULT_BK,
    interpret: bool = False,
) -> jax.Array:
    B, T, H, hd = q.shape
    bq = min(block_q, T)
    bk = min(block_k, T)
    if T % bq or T % bk:
        raise ValueError(f"T={T} must be divisible by block sizes ({bq},{bk})")
    nq, nk = T // bq, T // bk
    sm_scale = 1.0 / math.sqrt(hd)
    # Layout: fold (B, H) into one grid axis; heads vary fastest.
    qr = q.transpose(0, 2, 1, 3).reshape(B * H, T, hd)
    kr = k.transpose(0, 2, 1, 3).reshape(B * H, T, hd)
    vr = v.transpose(0, 2, 1, 3).reshape(B * H, T, hd)
    kernel = functools.partial(
        _flash_kernel,
        sm_scale=sm_scale,
        window=int(window),
        softcap=float(softcap),
        bq=bq,
        bk=bk,
        nk=nk,
        causal=causal,
    )
    out = pl.pallas_call(
        kernel,
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, T, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(B, H, T, hd).transpose(0, 2, 1, 3)
