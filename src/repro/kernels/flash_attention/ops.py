"""Jit'd public wrapper: GQA expansion + dispatch (pallas | interpret | ref)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import flash_attention_pallas
from .ref import flash_attention_ref


def _expand_kv(k: jax.Array, n_heads: int) -> jax.Array:
    n_kv = k.shape[2]
    if n_kv == n_heads:
        return k
    return jnp.repeat(k, n_heads // n_kv, axis=2)


@functools.partial(jax.jit, static_argnames=("window", "softcap", "causal", "impl", "block_q", "block_k"))
def flash_attention(
    q: jax.Array,  # [B, T, H, hd]
    k: jax.Array,  # [B, T, Hkv, hd]
    v: jax.Array,
    *,
    window: int = 1 << 30,
    softcap: float = 0.0,
    causal: bool = True,
    impl: str = "interpret",  # 'pallas' (TPU) | 'interpret' (CPU check) | 'ref'
    block_q: int = 128,
    block_k: int = 128,
) -> jax.Array:
    H = q.shape[2]
    k = _expand_kv(k, H)
    v = _expand_kv(v, H)
    if impl == "ref":
        return flash_attention_ref(q, k, v, window=window, softcap=softcap, causal=causal)
    return flash_attention_pallas(
        q, k, v, window=window, softcap=softcap, causal=causal,
        block_q=block_q, block_k=block_k, interpret=(impl == "interpret"),
    )
