"""Pure-jnp oracles for fused greedy NAV verification.

``spec_verify_ref`` is the rectangular [B, K+1, V] oracle (also the CPU
fallback behind ``ops.spec_verify(impl='ref')``).  ``spec_verify_ragged_ref``
is the unbatched per-session oracle the batched serving path is tested
against: it loops sessions one at a time with no padding, so any cross-
session leakage or padding bug in ``ops.spec_verify_batched`` shows up as a
mismatch.
"""

from __future__ import annotations

from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def spec_verify_ref(target_logits: jax.Array, draft_tokens: jax.Array, n_drafted: jax.Array):
    """Returns (n_accepted [B,1], correction [B,1], draft_logp [B,K])."""
    B, K1, V = target_logits.shape
    K = K1 - 1
    s = target_logits.astype(jnp.float32)
    greedy = jnp.argmax(s, axis=-1).astype(jnp.int32)  # [B, K1]
    pos = jnp.arange(K)[None, :]
    match = jnp.logical_and(greedy[:, :K] == draft_tokens, pos < n_drafted[:, None])
    n_acc = jnp.sum(jnp.cumprod(match.astype(jnp.int32), axis=-1), axis=-1).astype(jnp.int32)
    corr = jnp.take_along_axis(greedy, jnp.minimum(n_acc, K)[:, None], axis=-1)
    logp_all = jax.nn.log_softmax(s, axis=-1)
    logp = jnp.take_along_axis(logp_all[:, :K, :], draft_tokens[..., None], axis=-1)[..., 0]
    return n_acc[:, None], corr, logp


def spec_verify_ragged_ref(
    logits_seq: Sequence,  # B entries of [K_i+1, V]
    tokens_seq: Sequence,  # B entries of length-K_i ints
) -> List[Tuple[int, int, np.ndarray]]:
    """Per-session oracle: one unpadded ``spec_verify_ref`` call per session."""
    out: List[Tuple[int, int, np.ndarray]] = []
    for lg, tk in zip(logits_seq, tokens_seq):
        k = len(tk)
        toks = jnp.asarray(tk, jnp.int32).reshape(1, k) if k else jnp.zeros((1, 0), jnp.int32)
        na, corr, lp = spec_verify_ref(
            jnp.asarray(lg)[None], toks, jnp.asarray([k], jnp.int32)
        )
        out.append((int(na[0, 0]), int(corr[0, 0]), np.asarray(lp[0])))
    return out
