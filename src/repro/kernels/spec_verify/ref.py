"""Pure-jnp oracle for fused greedy NAV verification."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def spec_verify_ref(target_logits: jax.Array, draft_tokens: jax.Array, n_drafted: jax.Array):
    """Returns (n_accepted [B,1], correction [B,1], draft_logp [B,K])."""
    B, K1, V = target_logits.shape
    K = K1 - 1
    s = target_logits.astype(jnp.float32)
    greedy = jnp.argmax(s, axis=-1).astype(jnp.int32)  # [B, K1]
    pos = jnp.arange(K)[None, :]
    match = jnp.logical_and(greedy[:, :K] == draft_tokens, pos < n_drafted[:, None])
    n_acc = jnp.sum(jnp.cumprod(match.astype(jnp.int32), axis=-1), axis=-1).astype(jnp.int32)
    corr = jnp.take_along_axis(greedy, jnp.minimum(n_acc, K)[:, None], axis=-1)
    logp_all = jax.nn.log_softmax(s, axis=-1)
    logp = jnp.take_along_axis(logp_all[:, :K, :], draft_tokens[..., None], axis=-1)[..., 0]
    return n_acc[:, None], corr, logp
