"""Pure-jnp oracles for fused greedy NAV verification.

``spec_verify_ref`` is the rectangular [B, K+1, V] oracle (also the CPU
fallback behind ``ops.spec_verify(impl='ref')``).  ``spec_verify_ragged_ref``
is the unbatched per-session oracle the batched serving path is tested
against: it loops sessions one at a time with no padding, so any cross-
session leakage or padding bug in ``ops.spec_verify_batched`` shows up as a
mismatch.

Tree verification (``spec_verify_tree_ref``) generalizes the chain oracle to
a *packed token tree*: N draft nodes in topological order (every parent
precedes its children), ``parents[i] ∈ {-1, 0..i-1}`` with -1 marking a
root-level node.  The target logits carry N+1 rows — row 0 is the *anchor*
(logits after the committed prefix, which verify the root-level nodes) and
row 1+i is the target's distribution after the root→i path (which verifies
node i's children, and supplies the bonus token when i ends the accepted
path).  Greedy tree-NAV accepts node i iff the target's greedy token at its
parent's row equals ``tokens[i]`` AND every ancestor was accepted; the result
is the deepest accepted node (ties break toward the smallest packed index,
i.e. the highest-ranked sibling) plus the correction token from that node's
own row.
"""

from __future__ import annotations

import math
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def spec_verify_ref(target_logits: jax.Array, draft_tokens: jax.Array, n_drafted: jax.Array):
    """Returns (n_accepted [B,1], correction [B,1], draft_logp [B,K])."""
    B, K1, V = target_logits.shape
    K = K1 - 1
    s = target_logits.astype(jnp.float32)
    greedy = jnp.argmax(s, axis=-1).astype(jnp.int32)  # [B, K1]
    pos = jnp.arange(K)[None, :]
    match = jnp.logical_and(greedy[:, :K] == draft_tokens, pos < n_drafted[:, None])
    n_acc = jnp.sum(jnp.cumprod(match.astype(jnp.int32), axis=-1), axis=-1).astype(jnp.int32)
    corr = jnp.take_along_axis(greedy, jnp.minimum(n_acc, K)[:, None], axis=-1)
    logp_all = jax.nn.log_softmax(s, axis=-1)
    logp = jnp.take_along_axis(logp_all[:, :K, :], draft_tokens[..., None], axis=-1)[..., 0]
    return n_acc[:, None], corr, logp


def fused_target_logits(
    o: jax.Array,  # [B, K1, F] f32 attention outputs (F = H*hd)
    w: jax.Array,  # [F, Vp] f32 LM head, Vp a multiple of block_v
    *,
    block_v: int,
    v_true: int,
) -> jax.Array:
    """Blocked LM-head projection matching the fused kernel tile-for-tile.

    One ``jnp.dot([K1, F], [F, block_v])`` per (lane, vocab tile) — the
    EXACT shapes the fused kernel issues — then padded vocab ids masked to
    ``-1e30``, so composing this with ``spec_verify`` reproduces the fused
    launch bitwise (same values through the same arithmetic).
    """
    B, K1, F = o.shape
    Vp = w.shape[1]
    if Vp % block_v:
        raise ValueError(f"Vp={Vp} must be a multiple of block_v={block_v}")
    tiles = [w[:, j : j + block_v] for j in range(0, Vp, block_v)]
    rows = [jnp.concatenate([jnp.dot(o[b], t) for t in tiles], axis=-1) for b in range(B)]
    logits = jnp.stack(rows)
    ids = jnp.arange(Vp)[None, None, :]
    return jnp.where(ids >= v_true, -1e30, logits)


def spec_verify_fused_ref(
    q: jax.Array,  # [B, K+1, H, hd]
    k_pages: jax.Array,  # [P, bs, H, hd]
    v_pages: jax.Array,
    w: jax.Array,  # [F, Vp]
    block_tables: jax.Array,  # [B, G]
    lengths: jax.Array,  # [B, K+1] — valid KV length per query position
    draft_tokens: jax.Array,  # [B, K]
    n_drafted: jax.Array,  # [B]
    *,
    v_true: int,
    block_v: int,
    window: int = 1 << 30,
):
    """Fused-verify oracle: the unfused composition, stage by stage.

    Paged decode attention per query position (the ``decode_attention``
    oracle over position-flattened lanes), the blocked LM-head projection,
    then ``spec_verify_ref`` — the pure-JAX statement of what the one-launch
    kernel computes.
    """
    from ..decode_attention.ref import paged_decode_attention_ref

    B, K1, H, hd = q.shape
    qf = q.reshape(B * K1, H, hd)
    tf = jnp.repeat(jnp.asarray(block_tables, jnp.int32), K1, axis=0)
    lf = jnp.asarray(lengths, jnp.int32).reshape(B * K1)
    o = paged_decode_attention_ref(qf, k_pages, v_pages, tf, lf, window=window)
    o = o.reshape(B, K1, H * hd).astype(jnp.float32)
    logits = fused_target_logits(o, w.astype(jnp.float32), block_v=block_v, v_true=v_true)
    return spec_verify_ref(logits, draft_tokens, n_drafted)


def spec_verify_ragged_ref(
    logits_seq: Sequence,  # B entries of [K_i+1, V]
    tokens_seq: Sequence,  # B entries of length-K_i ints
) -> List[Tuple[int, int, np.ndarray]]:
    """Per-session oracle: one unpadded ``spec_verify_ref`` call per session."""
    out: List[Tuple[int, int, np.ndarray]] = []
    for lg, tk in zip(logits_seq, tokens_seq):
        k = len(tk)
        toks = jnp.asarray(tk, jnp.int32).reshape(1, k) if k else jnp.zeros((1, 0), jnp.int32)
        na, corr, lp = spec_verify_ref(
            jnp.asarray(lg)[None], toks, jnp.asarray([k], jnp.int32)
        )
        out.append((int(na[0, 0]), int(corr[0, 0]), np.asarray(lp[0])))
    return out


# --------------------------------------------------------------------------- #
# Tree-NAV (packed ancestor-mask) oracle
# --------------------------------------------------------------------------- #


def tree_topology(parents: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Derive (prow, depth, anc) from a packed parents array [B, N].

    prow[b, i]  — the target-logits row verifying node i: ``parents + 1``
                  (row 0 is the anchor, row 1+p is node p's own row).
    depth[b, i] — 1-based depth of node i (root-level nodes have depth 1).
    anc[b, i, j] — bool, node j lies on the root→i path (including j = i).

    Requires topological packing (``parents[i] < i``), which makes the parent
    one-hot strictly lower-triangular; the transitive closure then converges
    in ⌈log2 N⌉ boolean squarings.
    """
    B, N = parents.shape
    prow = (parents + 1).astype(jnp.int32)
    oh = parents[..., None] == jnp.arange(N, dtype=parents.dtype)[None, None, :]
    anc = jnp.eye(N, dtype=bool)[None] | oh  # self + direct parent
    for _ in range(max(int(math.ceil(math.log2(max(N, 2)))), 1)):
        anc = jnp.einsum("bij,bjk->bik", anc.astype(jnp.int32), anc.astype(jnp.int32)) > 0
    depth = jnp.sum(anc, axis=-1).astype(jnp.int32)
    return prow, depth, anc


def spec_verify_tree_ref(
    target_logits: jax.Array,  # [B, N+1, V] — row 0 anchor, row 1+i = node i
    tokens: jax.Array,  # [B, N] int32 packed node tokens
    parents: jax.Array,  # [B, N] int32, -1 = root level; parents[i] < i
    n_nodes: jax.Array,  # [B] int32 — valid node count (positions ≥ are pad)
):
    """Greedy tree-NAV oracle.

    Returns (n_accepted [B,1], best_node [B,1], correction [B,1], logp [B,N]):
    n_accepted is the depth of the deepest fully-accepted node (0 if no
    root-level node matches), best_node its packed index (-1 if none), and
    correction the target's greedy token at the accepted path's end (the
    anchor row when nothing is accepted).  ``logp[i]`` is the target log-prob
    of node i's token at its verify row (garbage at padded positions —
    callers slice ``logp[:n_nodes]``).
    """
    B, N1, V = target_logits.shape
    N = N1 - 1
    s = target_logits.astype(jnp.float32)
    greedy = jnp.argmax(s, axis=-1).astype(jnp.int32)  # [B, N1]
    prow, depth, anc = tree_topology(parents)
    g_at = jnp.take_along_axis(greedy, prow, axis=-1)  # [B, N]
    pos = jnp.arange(N)[None, :]
    valid = pos < n_nodes[:, None]
    match = jnp.logical_and(g_at == tokens, valid)
    # accepted[i] = every node on the root→i path matches (own match included
    # through anc[i, i]); pad nodes are masked out explicitly.
    accepted = jnp.all(match[:, None, :] | ~anc, axis=-1) & valid
    acc_depth = jnp.where(accepted, depth, 0)
    n_acc = jnp.max(acc_depth, axis=-1).astype(jnp.int32)  # [B]
    is_best = accepted & (acc_depth == n_acc[:, None]) & (n_acc[:, None] > 0)
    best = jnp.where(n_acc > 0, jnp.argmax(is_best, axis=-1).astype(jnp.int32), -1)
    best_row = jnp.where(n_acc > 0, best + 1, 0)
    corr = jnp.take_along_axis(greedy, best_row[:, None], axis=-1)
    logp_all = jax.nn.log_softmax(s, axis=-1)
    lp_rows = jnp.take_along_axis(logp_all, prow[:, :, None], axis=1)  # [B, N, V]
    logp = jnp.take_along_axis(lp_rows, tokens[..., None].astype(jnp.int32), axis=-1)[..., 0]
    return n_acc[:, None], best[:, None], corr, logp


def spec_verify_tree_ragged_ref(
    logits_seq: Sequence,  # B entries of [N_i+1, V]
    tokens_seq: Sequence,  # B entries of length-N_i ints
    parents_seq: Sequence,  # B entries of length-N_i ints
) -> List[Tuple[int, int, int, np.ndarray]]:
    """Per-session tree oracle: one unpadded ``spec_verify_tree_ref`` each."""
    out: List[Tuple[int, int, int, np.ndarray]] = []
    for lg, tk, pr in zip(logits_seq, tokens_seq, parents_seq):
        n = len(tk)
        na, best, corr, lp = spec_verify_tree_ref(
            jnp.asarray(lg)[None],
            jnp.asarray(tk, jnp.int32).reshape(1, n),
            jnp.asarray(pr, jnp.int32).reshape(1, n),
            jnp.asarray([n], jnp.int32),
        )
        out.append((int(na[0, 0]), int(best[0, 0]), int(corr[0, 0]), np.asarray(lp[0])))
    return out
