"""Jit'd wrappers for the fused NAV verify kernel.

``spec_verify`` is the rectangular entry ([B, K+1, V] with per-row
``n_drafted``).  ``spec_verify_batched`` is the serving entry used by the
continuous-batching cloud verifier (runtime/server.py): it takes **ragged**
per-session requests (different draft lengths K_i), pads them into one
[B', Kmax+1, V] launch, and unpacks per-session results.  Shapes are
bucketed to powers of two so a serving process compiles a handful of
variants instead of one per (B, Kmax) pair.

Padded rows/positions are provably inert (see kernel.py "padding
invariants"): acceptance is masked by ``pos < n_drafted``, the correction
index never exceeds ``n_drafted``, and padded log-prob lanes are sliced off
before returning.
"""

from __future__ import annotations

import functools
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernel import spec_verify_fused_pallas, spec_verify_pallas, spec_verify_tree_pallas
from .ref import spec_verify_fused_ref, spec_verify_ref, spec_verify_tree_ref, tree_topology


@functools.partial(jax.jit, static_argnames=("impl", "block_v"))
def spec_verify(
    target_logits: jax.Array,  # [B, K+1, V]
    draft_tokens: jax.Array,  # [B, K]
    n_drafted: jax.Array,  # [B]
    *,
    impl: str = "interpret",
    block_v: int = 2048,
):
    if impl == "ref":
        return spec_verify_ref(target_logits, draft_tokens, n_drafted)
    return spec_verify_pallas(target_logits, draft_tokens, n_drafted, block_v=block_v, interpret=(impl == "interpret"))


def _next_pow2(n: int) -> int:
    return 1 << max(int(n) - 1, 0).bit_length()


@functools.partial(
    jax.jit, static_argnames=("v_true", "impl", "block_v", "window")
)
def spec_verify_fused(
    q: jax.Array,  # [B, K+1, H, hd] — per-position queries
    k_pages: jax.Array,  # [P, bs, Hkv, hd] (int8 payload when quant is given)
    v_pages: jax.Array,
    w: jax.Array,  # [H*hd, V] LM head (padded to a block_v multiple here)
    block_tables: jax.Array,  # [B, G] i32 physical page ids
    lengths: jax.Array,  # [B, K+1] i32 valid KV length per query position
    draft_tokens: jax.Array,  # [B, K] i32
    n_drafted: jax.Array,  # [B] i32
    *,
    v_true: Optional[int] = None,
    impl: str = "interpret",
    block_v: int = 2048,
    window: int = 1 << 30,
    quant=None,  # (k_scale, k_zero, v_scale, v_zero), each [P, bs, Hkv] f32
):
    """ONE-launch chain verify: paged target attention + LM head + NAV scan.

    The rectangular fused entry: instead of precomputed ``[B, K+1, V]``
    logits it takes the target's per-position queries, the paged KV pool
    slices, the LM head, and the sessions' block tables, and returns the
    ``spec_verify`` contract ``(n_accepted [B,1], correction [B,1],
    logp [B,K])`` from a single Pallas launch (vs attention-launch +
    verify-launch unfused).  ``lengths[b, i]`` is the valid KV length seen
    by query position ``i`` (causal: the serving entry passes
    ``base + i``).  With ``quant`` the pages are int8 and dequantized
    in-kernel (``models/paged_kv.py`` affine layout).  Bit-exact vs the
    unfused composition per ``tests/test_spec_verify_fused.py``.
    """
    H = q.shape[2]
    n_kv = k_pages.shape[2]
    if n_kv != H:
        k_pages = jnp.repeat(k_pages, H // n_kv, axis=2)
        v_pages = jnp.repeat(v_pages, H // n_kv, axis=2)
        if quant is not None:
            quant = tuple(jnp.repeat(p, H // n_kv, axis=2) for p in quant)
    V = w.shape[1]
    if v_true is None:
        v_true = V
    bv = min(block_v, _next_pow2(V))
    Vp = -(-V // bv) * bv
    if Vp > V:  # zero columns; the kernels mask ids >= v_true to -1e30
        w = jnp.pad(w, ((0, 0), (0, Vp - V)))
    if impl == "ref":
        if quant is not None:
            # Local import: decode_attention.ops imports pad_block_tables
            # from this module, so a top-level import would be circular.
            from ..decode_attention.ref import dequantize_pages

            ks, kz, vs, vz = quant
            k_pages = dequantize_pages(k_pages, ks, kz)
            v_pages = dequantize_pages(v_pages, vs, vz)
        return spec_verify_fused_ref(
            q, k_pages, v_pages, w, block_tables, lengths, draft_tokens, n_drafted,
            v_true=v_true, block_v=bv, window=window,
        )
    return spec_verify_fused_pallas(
        q, k_pages, v_pages, w, block_tables, lengths, draft_tokens, n_drafted,
        v_true=v_true, block_v=bv, window=window, quant=quant,
        interpret=(impl == "interpret"),
    )


def spec_verify_fused_batched(
    q_seq: Sequence,  # B entries of [K_i+1, H, hd] per-position queries
    tokens_seq: Sequence,  # B entries of length-K_i int sequences
    block_tables_seq: Sequence,  # B ragged KV block tables
    base_lengths: Sequence,  # B ints — KV length visible to query position 0
    k_pages: jax.Array,
    v_pages: jax.Array,
    w: jax.Array,
    *,
    impl: str = "interpret",
    block_v: int = 2048,
    bucket: bool = True,
    window: int = 1 << 30,
    pad_page_id: int = 0,
    quant=None,
) -> List[Tuple[int, int, np.ndarray]]:
    """Ragged serving entry for the fused verify — one launch for B sessions.

    The fused twin of ``spec_verify_batched``'s ``batched_logits_fn`` path,
    with the forward folded INTO the verify launch: pads queries, tokens,
    block tables (``pad_page_id`` — pass the pool's ``sentinel_page``), and
    per-position lengths (position ``i`` of session ``s`` sees
    ``base_lengths[s] + i``; pad rows/positions see 0, making them inert)
    under the same pow2 bucketing, launches once, and unpacks
    ``(n_accepted, correction, logp[K_i])`` per session in input order.
    """
    if not (len(q_seq) == len(tokens_seq) == len(block_tables_seq) == len(base_lengths)):
        raise ValueError("need one (queries, tokens, table, base_length) per session")
    if not len(tokens_seq):
        raise ValueError("need at least one session")
    ks = [len(t) for t in tokens_seq]
    for qi, k in zip(q_seq, ks):
        if qi.shape[0] != k + 1:
            raise ValueError(f"queries must be [K_i+1, H, hd]; got {qi.shape} for K_i={k}")
    B, kmax = len(ks), max(max(ks, default=0), 1)
    Bp = _next_pow2(B) if bucket else B
    Kp = _next_pow2(kmax) if bucket else kmax
    H, hd = q_seq[0].shape[1], q_seq[0].shape[2]
    qpad = np.zeros((Bp, Kp + 1, H, hd), np.float32)
    tokens = np.zeros((Bp, Kp), np.int32)
    nd = np.zeros((Bp,), np.int32)
    lengths = np.zeros((Bp, Kp + 1), np.int32)
    for i, (qi, tk, k, base) in enumerate(zip(q_seq, tokens_seq, ks, base_lengths)):
        qpad[i, : k + 1] = np.asarray(qi, np.float32)
        tokens[i, :k] = np.asarray(tk, np.int32)
        nd[i] = k
        lengths[i, : k + 1] = int(base) + np.arange(k + 1)
    tables = pad_block_tables(
        block_tables_seq, batch_pad=Bp, bucket=bucket, pad_id=pad_page_id
    )
    na, corr, logp = spec_verify_fused(
        jnp.asarray(qpad),
        k_pages,
        v_pages,
        w,
        jnp.asarray(tables),
        jnp.asarray(lengths),
        jnp.asarray(tokens),
        jnp.asarray(nd),
        impl=impl,
        block_v=block_v,
        window=window,
        quant=quant,
    )
    na, corr, logp = np.asarray(na), np.asarray(corr), np.asarray(logp)
    return [(int(na[i, 0]), int(corr[i, 0]), logp[i, : ks[i]]) for i in range(B)]


def pad_block_tables(
    tables_seq: Sequence, *, batch_pad: int, bucket: bool = True, pad_id: int = 0
) -> np.ndarray:
    """Pad ragged per-session KV block tables into one ``[Bp, Gp]`` int32 array.

    The serving-side companion of the batched verify entries: a paged target
    forward (``kernels.decode_attention`` paged path) consumes one block
    table per admitted session, and those tables are ragged exactly like the
    draft lengths.  They are padded with the SAME pow2 bucketing as the
    logits batch (``batch_pad`` = the entry's ``Bp``) so a serving process
    compiles one shape family for the fused forward+verify dispatch.  Pad
    entries carry ``pad_id``; pass the pool's zero-filled ``sentinel_page``
    (as the serving backend does) so padded lanes can only ever DMA the
    sentinel — never a page owned by another session.  The legacy default 0
    is a *live* page id and is only safe because attention masks pad
    positions by ``lengths``; see ``docs/kernels.md``.
    """
    gmax = max((len(t) for t in tables_seq), default=0)
    Gp = max(_next_pow2(gmax) if bucket else gmax, 1)
    out = np.full((batch_pad, Gp), pad_id, np.int32)
    for i, t in enumerate(tables_seq):
        if len(t):
            out[i, : len(t)] = np.asarray(t, np.int32)
    return out


def spec_verify_batched(
    logits_seq: Optional[Sequence],  # B entries of [K_i+1, V]; None with batched_logits_fn
    tokens_seq: Sequence,  # B entries of length-K_i int sequences
    *,
    impl: str = "ref",
    block_v: int = 2048,
    bucket: bool = True,
    block_tables_seq: Optional[Sequence] = None,  # B ragged KV block tables
    batched_logits_fn: Optional[Callable] = None,
    pad_page_id: int = 0,
) -> List[Tuple[int, int, np.ndarray]]:
    """Verify B sessions with ragged draft lengths in ONE launch.

    Returns a list of ``(n_accepted, correction_token, logp[K_i])`` in input
    order.  With ``bucket=True`` the batch and draft dimensions are padded to
    the next power of two (padding rows carry ``n_drafted = 0`` and are
    discarded), bounding the number of compiled shapes under serving load.

    **Paged target forward.**  With ``batched_logits_fn`` the entry owns the
    whole fused dispatch: it pads tokens, per-session ``n_drafted``, and the
    sessions' KV ``block_tables_seq`` (same ``Bp`` bucketing, via
    ``pad_block_tables``), then calls
    ``batched_logits_fn(tokens[Bp, Kp], n_drafted[Bp], tables[Bp, Gp]|None)``
    for one batched ``[Bp, Kp+1, V]`` target forward (paged attention over
    the block tables in a real deployment) before the NAV reduction —
    instead of accepting per-session precomputed ``logits_seq``.
    """
    if batched_logits_fn is None:
        if logits_seq is None or len(logits_seq) != len(tokens_seq) or not len(tokens_seq):
            raise ValueError("need equal, non-empty logits/tokens sequences")
    elif logits_seq is not None:
        raise ValueError("pass logits_seq OR batched_logits_fn, not both")
    if block_tables_seq is not None and len(block_tables_seq) != len(tokens_seq):
        raise ValueError("need one block table per session")
    ks = [len(t) for t in tokens_seq]
    B, kmax = len(ks), max(max(ks, default=0), 1)
    Bp = _next_pow2(B) if bucket else B
    Kp = _next_pow2(kmax) if bucket else kmax
    tokens = np.zeros((Bp, Kp), np.int32)
    nd = np.zeros((Bp,), np.int32)
    for i, (tk, k) in enumerate(zip(tokens_seq, ks)):
        tokens[i, :k] = np.asarray(tk, np.int32)
        nd[i] = k

    if batched_logits_fn is not None:
        tables = (
            pad_block_tables(block_tables_seq, batch_pad=Bp, bucket=bucket, pad_id=pad_page_id)
            if block_tables_seq is not None
            else None
        )
        full = np.asarray(batched_logits_fn(tokens, nd, tables), np.float32)
        if full.shape[:2] != (Bp, Kp + 1):
            raise ValueError(f"batched_logits_fn must return [Bp, Kp+1, V]; got {full.shape}")
        logits_rows = full
        V = full.shape[-1]
    else:
        for lg, k in zip(logits_seq, ks):
            if lg.ndim != 2 or lg.shape[0] != k + 1:
                raise ValueError(f"logits must be [K_i+1, V]; got {lg.shape} for K_i={k}")
        V = logits_seq[0].shape[-1]
        if any(lg.shape[-1] != V for lg in logits_seq):
            raise ValueError("all sessions must share one (padded) vocab size")
        logits_rows = None

    # Pallas needs V % block_v == 0: pad the vocab with -inf lanes (inert —
    # they never win the argmax, add 0 to the logsumexp, and no draft token
    # id can address them), keeping the documented VMEM tile budget.
    bv = min(block_v, _next_pow2(V))
    Vp = -(-V // bv) * bv
    logits = np.zeros((Bp, Kp + 1, Vp), np.float32)
    if Vp > V:
        logits[:, :, V:] = -1e30  # only the pad lanes need the -inf sweep
    if logits_rows is not None:
        logits[:, :, :V] = logits_rows
    else:
        for i, (lg, k) in enumerate(zip(logits_seq, ks)):
            logits[i, : k + 1, :V] = np.asarray(lg, np.float32)

    na, corr, logp = spec_verify(
        jnp.asarray(logits), jnp.asarray(tokens), jnp.asarray(nd), impl=impl, block_v=bv
    )
    na, corr, logp = np.asarray(na), np.asarray(corr), np.asarray(logp)
    return [(int(na[i, 0]), int(corr[i, 0]), logp[i, : ks[i]]) for i in range(B)]


# --------------------------------------------------------------------------- #
# Tree-NAV entries
# --------------------------------------------------------------------------- #


def tree_path(parents: Sequence[int], node: int) -> List[int]:
    """Packed node indices along the root→``node`` path (inclusive, in order).

    Returns [] for ``node < 0`` (the no-acceptance sentinel), so callers can
    feed ``best_node`` from the verifier straight through.
    """
    path: List[int] = []
    i = int(node)
    while i >= 0:
        path.append(i)
        i = int(parents[i])
    path.reverse()
    return path


@functools.partial(jax.jit, static_argnames=("impl", "block_v"))
def spec_verify_tree(
    target_logits: jax.Array,  # [B, N+1, V] — row 0 anchor, row 1+i = node i
    tokens: jax.Array,  # [B, N]
    parents: jax.Array,  # [B, N] int32, -1 = root level, parents[i] < i
    n_nodes: jax.Array,  # [B]
    *,
    impl: str = "interpret",
    block_v: int = 2048,
):
    """Greedy tree-NAV: (n_accepted [B,1], best_node [B,1], corr [B,1], logp [B,N])."""
    if impl == "ref":
        return spec_verify_tree_ref(target_logits, tokens, parents, n_nodes)
    prow, depth, anc = tree_topology(jnp.asarray(parents, jnp.int32))
    return spec_verify_tree_pallas(
        target_logits,
        tokens,
        prow,
        depth,
        anc,
        n_nodes,
        block_v=block_v,
        interpret=(impl == "interpret"),
    )


def spec_verify_tree_batched(
    logits_seq: Optional[Sequence],  # B entries of [N_i+1, V]; None with batched_logits_fn
    tokens_seq: Sequence,  # B entries of length-N_i int sequences
    parents_seq: Sequence,  # B entries of length-N_i int sequences
    *,
    impl: str = "ref",
    block_v: int = 2048,
    bucket: bool = True,
    block_tables_seq: Optional[Sequence] = None,  # B ragged KV block tables
    batched_logits_fn: Optional[Callable] = None,
    pad_page_id: int = 0,
) -> List[Tuple[int, List[int], int, np.ndarray]]:
    """Verify B sessions' ragged token TREES in ONE padded launch.

    Returns, per session in input order, ``(n_accepted, path, correction,
    logp[N_i])`` where ``path`` is the accepted root→leaf node-index list
    (length ``n_accepted``).  Trees are padded by NODE count with the same
    pow2 bucketing as the chain entry; pad nodes carry ``parents = -1`` and
    pad rows ``n_nodes = 0``, both provably inert (kernel.py invariants).

    Like the chain entry, ``batched_logits_fn`` replaces per-session
    precomputed logits with ONE batched target forward over the padded
    arrays: ``batched_logits_fn(tokens[Bp, Np], parents[Bp, Np],
    n_nodes[Bp], tables[Bp, Gp]|None) -> [Bp, Np+1, V]`` — an
    ancestor-masked paged-attention forward in a real deployment, with the
    sessions' KV ``block_tables_seq`` padded by ``pad_block_tables`` under
    the same ``Bp`` bucketing.
    """
    if not (len(tokens_seq) == len(parents_seq)) or not len(tokens_seq):
        raise ValueError("need equal, non-empty tokens/parents sequences")
    if batched_logits_fn is None:
        if logits_seq is None or len(logits_seq) != len(tokens_seq):
            raise ValueError("need equal, non-empty logits/tokens/parents sequences")
    elif logits_seq is not None:
        raise ValueError("pass logits_seq OR batched_logits_fn, not both")
    if block_tables_seq is not None and len(block_tables_seq) != len(tokens_seq):
        raise ValueError("need one block table per session")
    ns = [len(t) for t in tokens_seq]
    for pr, n in zip(parents_seq, ns):
        if len(pr) != n:
            raise ValueError(f"parents length {len(pr)} != node count {n}")
        for i, p in enumerate(pr):
            if not (-1 <= int(p) < i):
                raise ValueError(f"parents must be topologically packed; parents[{i}]={p}")
    B, nmax = len(ns), max(max(ns), 1)
    Bp = _next_pow2(B) if bucket else B
    Np = _next_pow2(nmax) if bucket else nmax
    tokens = np.zeros((Bp, Np), np.int32)
    parents = np.full((Bp, Np), -1, np.int32)
    nn = np.zeros((Bp,), np.int32)
    for i, (tk, pr, n) in enumerate(zip(tokens_seq, parents_seq, ns)):
        tokens[i, :n] = np.asarray(tk, np.int32)
        parents[i, :n] = np.asarray(pr, np.int32)
        nn[i] = n

    if batched_logits_fn is not None:
        tables = (
            pad_block_tables(block_tables_seq, batch_pad=Bp, bucket=bucket, pad_id=pad_page_id)
            if block_tables_seq is not None
            else None
        )
        full = np.asarray(batched_logits_fn(tokens, parents, nn, tables), np.float32)
        if full.shape[:2] != (Bp, Np + 1):
            raise ValueError(f"batched_logits_fn must return [Bp, Np+1, V]; got {full.shape}")
        V = full.shape[-1]
    else:
        for lg, n in zip(logits_seq, ns):
            if lg.ndim != 2 or lg.shape[0] != n + 1:
                raise ValueError(f"logits must be [N_i+1, V]; got {lg.shape} for N_i={n}")
        V = logits_seq[0].shape[-1]
        if any(lg.shape[-1] != V for lg in logits_seq):
            raise ValueError("all sessions must share one (padded) vocab size")
        full = None

    bv = min(block_v, _next_pow2(V))
    Vp = -(-V // bv) * bv
    logits = np.zeros((Bp, Np + 1, Vp), np.float32)
    if Vp > V:
        logits[:, :, V:] = -1e30  # inert pad lanes (see chain entry)
    if full is not None:
        logits[:, :, :V] = full
    else:
        for i, (lg, n) in enumerate(zip(logits_seq, ns)):
            logits[i, : n + 1, :V] = np.asarray(lg, np.float32)

    na, best, corr, logp = spec_verify_tree(
        jnp.asarray(logits), jnp.asarray(tokens), jnp.asarray(parents), jnp.asarray(nn),
        impl=impl, block_v=bv,
    )
    na, best, corr, logp = (np.asarray(x) for x in (na, best, corr, logp))
    out: List[Tuple[int, List[int], int, np.ndarray]] = []
    for i in range(B):
        path = tree_path(parents[i], int(best[i, 0]))
        out.append((int(na[i, 0]), path, int(corr[i, 0]), logp[i, : ns[i]]))
    return out
