"""Jit'd wrapper for the fused NAV verify kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import spec_verify_pallas
from .ref import spec_verify_ref


@functools.partial(jax.jit, static_argnames=("impl", "block_v"))
def spec_verify(
    target_logits: jax.Array,  # [B, K+1, V]
    draft_tokens: jax.Array,  # [B, K]
    n_drafted: jax.Array,  # [B]
    *,
    impl: str = "interpret",
    block_v: int = 2048,
):
    if impl == "ref":
        return spec_verify_ref(target_logits, draft_tokens, n_drafted)
    return spec_verify_pallas(target_logits, draft_tokens, n_drafted, block_v=block_v, interpret=(impl == "interpret"))
