from . import ops, ref
from .kernel import spec_verify_fused_pallas, spec_verify_pallas, spec_verify_tree_pallas
from .ops import (
    pad_block_tables,
    spec_verify,
    spec_verify_batched,
    spec_verify_fused,
    spec_verify_fused_batched,
    spec_verify_tree,
    spec_verify_tree_batched,
    tree_path,
)
from .ref import (
    fused_target_logits,
    spec_verify_fused_ref,
    spec_verify_ref,
    spec_verify_ragged_ref,
    spec_verify_tree_ragged_ref,
    spec_verify_tree_ref,
    tree_topology,
)

__all__ = [
    "fused_target_logits",
    "pad_block_tables",
    "spec_verify",
    "spec_verify_batched",
    "spec_verify_fused",
    "spec_verify_fused_batched",
    "spec_verify_fused_pallas",
    "spec_verify_fused_ref",
    "spec_verify_pallas",
    "spec_verify_ref",
    "spec_verify_ragged_ref",
    "spec_verify_tree",
    "spec_verify_tree_batched",
    "spec_verify_tree_pallas",
    "spec_verify_tree_ragged_ref",
    "spec_verify_tree_ref",
    "tree_path",
    "tree_topology",
    "ops",
    "ref",
]
