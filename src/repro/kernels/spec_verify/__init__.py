from . import ops, ref
from .kernel import spec_verify_pallas
from .ops import spec_verify
from .ref import spec_verify_ref

__all__ = ["spec_verify", "spec_verify_pallas", "spec_verify_ref", "ops", "ref"]
