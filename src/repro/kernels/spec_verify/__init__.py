from . import ops, ref
from .kernel import spec_verify_pallas
from .ops import spec_verify, spec_verify_batched
from .ref import spec_verify_ref, spec_verify_ragged_ref

__all__ = [
    "spec_verify",
    "spec_verify_batched",
    "spec_verify_pallas",
    "spec_verify_ref",
    "spec_verify_ragged_ref",
    "ops",
    "ref",
]
