"""Pallas TPU fused speculative-verification (greedy NAV) kernel.

The NAV step's post-processing is memory-bound on the target logits
[B, K+1, V] (V up to 262k padded): XLA's naive lowering reads the logits
once for argmax, once for log-softmax, and once for the draft-token gather.
This kernel fuses all three into ONE pass over the vocabulary:

    per (lane, vocab-block): running (max, argmax, logsumexp) per position
    + gather of each draft token's logit when its id falls in the block;
    final block → n_accepted, correction token, draft-token log-probs.

Grid: (B, num_vocab_blocks), vocab dimension "arbitrary" (sequential) with
running state in VMEM scratch.  K+1 ≤ 16 positions; vocab blocks of 2048
keep the [K+1, BV] score tile ≤ 128 KB in VMEM.

Padding invariants (relied on by ``ops.spec_verify_batched``, which packs
ragged multi-session requests into one rectangular launch):

* rows with ``n_drafted = 0`` produce ``n_accepted = 0`` and touch nothing
  else — whole padding rows (zero logits, zero tokens) are inert;
* positions ``>= n_drafted`` never accept (the match is masked by
  ``pos < n_drafted``), and the correction index ``min(n_accepted, K)``
  never exceeds ``n_drafted``, so per-row padding columns beyond a
  session's real draft length cannot leak into its outputs;
* ``logp`` lanes at padded positions carry garbage by design — callers
  slice ``logp[:K_i]``.

``_tree_verify_kernel`` is the tree-NAV generalization: N packed tree nodes
verified against N+1 logits rows (row 0 = anchor, row 1+i = node i), where
node i is scored by its PARENT's row (``prow = parents + 1``) and acceptance
propagates along the packed ancestor mask ``anc[i, j]`` — accepted(i) =
∀j on root→i path: match(j).  The finalize step reduces to the deepest
accepted node (ties → smallest packed index), its depth, and the correction
token from that node's own row.  The same padding invariants hold with
``n_drafted`` replaced by ``n_nodes``: pad nodes never match, and real
nodes' ancestor sets contain only real nodes, so pad nodes cannot veto an
acceptance.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .._compat import CompilerParams

DEFAULT_BV = 2048
NEG_INF = -1e30


def _verify_kernel(
    logits_ref,  # [1, K1, BV] f32/bf16 target logits block
    tokens_ref,  # [1, K] i32 draft tokens (SMEM)
    nd_ref,  # [1, 1] i32 n_drafted (SMEM)
    nacc_ref,  # [1, 1] i32 out
    corr_ref,  # [1, 1] i32 out
    logp_ref,  # [1, K] f32 out — log P_target(draft token)
    m_scr,  # [K1] f32 running max
    arg_scr,  # [K1] i32 running argmax
    lse_scr,  # [K1] f32 running sum exp (shifted by m)
    tok_scr,  # [K1] f32 draft-token logits (position i holds logit of draft i)
    *,
    bv: int,
    nv: int,
    k1: int,
):
    vb = pl.program_id(1)

    @pl.when(vb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        arg_scr[...] = jnp.zeros_like(arg_scr)
        lse_scr[...] = jnp.zeros_like(lse_scr)
        tok_scr[...] = jnp.full_like(tok_scr, NEG_INF)

    s = logits_ref[0].astype(jnp.float32)  # [K1, BV]
    ids = vb * bv + jax.lax.broadcasted_iota(jnp.int32, (k1, bv), 1)
    blk_max = jnp.max(s, axis=-1)  # [K1]
    blk_arg = jnp.min(jnp.where(s == blk_max[:, None], ids, jnp.int32(2**30)), axis=-1)
    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, blk_max)
    lse_scr[...] = lse_scr[...] * jnp.exp(m_prev - m_new) + jnp.sum(jnp.exp(s - m_new[:, None]), axis=-1)
    arg_scr[...] = jnp.where(blk_max > m_prev, blk_arg, arg_scr[...])
    m_scr[...] = m_new
    # Gather draft-token logits owned by this block: position i's draft token
    # is tokens[i] and is verified against logits row i (row K is the bonus).
    K = k1 - 1
    tok_row = jnp.concatenate(
        [tokens_ref[0, :].reshape(K), jnp.full((1,), -1, jnp.int32)]
    )  # [K1]
    hit = ids == tok_row[:, None]  # [K1, BV]
    gathered = jnp.sum(jnp.where(hit, s, 0.0), axis=-1)
    tok_scr[...] = jnp.where(jnp.any(hit, axis=-1), gathered, tok_scr[...])

    @pl.when(vb == nv - 1)
    def _finalize():
        greedy = arg_scr[...]  # [K1]
        lse = m_scr[...] + jnp.log(jnp.maximum(lse_scr[...], 1e-30))
        n_d = nd_ref[0, 0]
        pos = jax.lax.broadcasted_iota(jnp.int32, (k1,), 0)
        match = jnp.logical_and(greedy == tok_row, pos < n_d)[:K]
        n_acc = jnp.sum(jnp.cumprod(match.astype(jnp.int32)))
        nacc_ref[0, 0] = n_acc
        corr_ref[0, 0] = jnp.sum(jnp.where(pos == jnp.minimum(n_acc, K), greedy, 0))
        logp_ref[0, :] = (tok_scr[...] - lse)[:K]


def _tree_verify_kernel(
    logits_ref,  # [1, N1, BV] f32/bf16 target logits block (row 0 = anchor)
    tokens_ref,  # [1, N] i32 packed node tokens (SMEM)
    prow_ref,  # [1, N] i32 verify row per node = parents + 1 (SMEM)
    depth_ref,  # [1, N] i32 1-based node depth (SMEM)
    nn_ref,  # [1, 1] i32 n_nodes (SMEM)
    anc_ref,  # [1, N, N] i32 packed ancestor mask (anc[i,j]=1: j on root→i path)
    nacc_ref,  # [1, 1] i32 out — depth of deepest accepted node
    best_ref,  # [1, 1] i32 out — packed index of that node (-1 if none)
    corr_ref,  # [1, 1] i32 out — correction/bonus token
    logp_ref,  # [1, N] f32 out — log P_target(node token) at its verify row
    m_scr,  # [N1] f32 running max
    arg_scr,  # [N1] i32 running argmax
    lse_scr,  # [N1] f32 running sum exp (shifted by m)
    tok_scr,  # [N] f32 node-token logits gathered at each node's verify row
    *,
    bv: int,
    nv: int,
    n1: int,
):
    vb = pl.program_id(1)
    N = n1 - 1

    @pl.when(vb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        arg_scr[...] = jnp.zeros_like(arg_scr)
        lse_scr[...] = jnp.zeros_like(lse_scr)
        tok_scr[...] = jnp.full_like(tok_scr, NEG_INF)

    s = logits_ref[0].astype(jnp.float32)  # [N1, BV]
    ids1 = vb * bv + jax.lax.broadcasted_iota(jnp.int32, (n1, bv), 1)
    blk_max = jnp.max(s, axis=-1)  # [N1]
    blk_arg = jnp.min(jnp.where(s == blk_max[:, None], ids1, jnp.int32(2**30)), axis=-1)
    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, blk_max)
    lse_scr[...] = lse_scr[...] * jnp.exp(m_prev - m_new) + jnp.sum(jnp.exp(s - m_new[:, None]), axis=-1)
    arg_scr[...] = jnp.where(blk_max > m_prev, blk_arg, arg_scr[...])
    m_scr[...] = m_new
    # Gather each node's token logit from its VERIFY row (unlike the chain
    # kernel, node i is scored by row prow[i], not row i): a one-hot matmul
    # re-indexes the [N1, BV] tile to [N, BV] before the in-block id match.
    tok_row = tokens_ref[0, :].reshape(N)  # [N]
    prow = prow_ref[0, :].reshape(N)  # [N]
    row_ids = jax.lax.broadcasted_iota(jnp.int32, (N, n1), 1)
    onehot = (row_ids == prow[:, None]).astype(jnp.float32)  # [N, N1]
    s_at = jnp.dot(onehot, s, preferred_element_type=jnp.float32)  # [N, BV]
    ids = vb * bv + jax.lax.broadcasted_iota(jnp.int32, (N, bv), 1)
    hit = ids == tok_row[:, None]  # [N, BV]
    gathered = jnp.sum(jnp.where(hit, s_at, 0.0), axis=-1)
    tok_scr[...] = jnp.where(jnp.any(hit, axis=-1), gathered, tok_scr[...])

    @pl.when(vb == nv - 1)
    def _finalize():
        greedy = arg_scr[...]  # [N1]
        lse = m_scr[...] + jnp.log(jnp.maximum(lse_scr[...], 1e-30))
        n_d = nn_ref[0, 0]
        depth = depth_ref[0, :].reshape(N)
        oh = row_ids == prow[:, None]  # [N, N1]
        g_at = jnp.sum(jnp.where(oh, greedy[None, :], 0), axis=-1)  # [N]
        lse_at = jnp.sum(jnp.where(oh, lse[None, :], 0.0), axis=-1)
        pos = jax.lax.broadcasted_iota(jnp.int32, (N,), 0)
        valid = pos < n_d
        match = jnp.logical_and(g_at == tok_row, valid)
        anc = anc_ref[0] != 0  # [N, N]
        # accepted[i] = all nodes on root→i path match (anc[i,i] covers i).
        accepted = jnp.logical_and(jnp.all(jnp.logical_or(match[None, :], ~anc), axis=-1), valid)
        acc_depth = jnp.where(accepted, depth, 0)
        n_acc = jnp.max(acc_depth)
        best = jnp.min(jnp.where(jnp.logical_and(accepted, acc_depth == n_acc), pos, jnp.int32(2**30)))
        best = jnp.where(n_acc > 0, best, -1)
        best_row = jnp.where(n_acc > 0, best + 1, 0)
        ids_n1 = jax.lax.broadcasted_iota(jnp.int32, (n1,), 0)
        nacc_ref[0, 0] = n_acc
        best_ref[0, 0] = best
        corr_ref[0, 0] = jnp.sum(jnp.where(ids_n1 == best_row, greedy, 0))
        logp_ref[0, :] = tok_scr[...] - lse_at


def spec_verify_tree_pallas(
    target_logits: jax.Array,  # [B, N+1, V] — row 0 anchor, row 1+i = node i
    tokens: jax.Array,  # [B, N] i32
    prow: jax.Array,  # [B, N] i32 (parents + 1)
    depth: jax.Array,  # [B, N] i32 (1-based)
    anc: jax.Array,  # [B, N, N] i32/bool packed ancestor mask
    n_nodes: jax.Array,  # [B] i32
    *,
    block_v: int = DEFAULT_BV,
    interpret: bool = False,
):
    B, N1, V = target_logits.shape
    N = N1 - 1
    if N < 1:
        raise ValueError("tree verification needs at least one node")
    if N1 > 128:
        raise ValueError(f"N+1={N1} exceeds the [N1] VMEM scratch budget (max 128)")
    bv = min(block_v, V)
    if V % bv:
        raise ValueError(f"V={V} must be divisible by block_v={bv}")
    nv = V // bv
    kernel = functools.partial(_tree_verify_kernel, bv=bv, nv=nv, n1=N1)
    return pl.pallas_call(
        kernel,
        grid=(B, nv),
        in_specs=[
            pl.BlockSpec((1, N1, bv), lambda b, j: (b, 0, j)),
            pl.BlockSpec((1, N), lambda b, j: (b, 0), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, N), lambda b, j: (b, 0), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, N), lambda b, j: (b, 0), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1), lambda b, j: (b, 0), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, N, N), lambda b, j: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1), lambda b, j: (b, 0), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1), lambda b, j: (b, 0), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1), lambda b, j: (b, 0), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, N), lambda b, j: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, 1), jnp.int32),
            jax.ShapeDtypeStruct((B, 1), jnp.int32),
            jax.ShapeDtypeStruct((B, 1), jnp.int32),
            jax.ShapeDtypeStruct((B, N), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((N1,), jnp.float32),
            pltpu.VMEM((N1,), jnp.int32),
            pltpu.VMEM((N1,), jnp.float32),
            pltpu.VMEM((N,), jnp.float32),
        ],
        compiler_params=CompilerParams(dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(
        target_logits,
        tokens.astype(jnp.int32),
        prow.astype(jnp.int32),
        depth.astype(jnp.int32),
        n_nodes.reshape(B, 1).astype(jnp.int32),
        anc.astype(jnp.int32),
    )


def spec_verify_pallas(
    target_logits: jax.Array,  # [B, K+1, V]
    draft_tokens: jax.Array,  # [B, K] i32
    n_drafted: jax.Array,  # [B] i32
    *,
    block_v: int = DEFAULT_BV,
    interpret: bool = False,
):
    B, K1, V = target_logits.shape
    K = K1 - 1
    if K1 > 128:
        raise ValueError(f"K+1={K1} exceeds the [K1] VMEM scratch budget (max 128)")
    bv = min(block_v, V)
    if V % bv:
        raise ValueError(f"V={V} must be divisible by block_v={bv}")
    nv = V // bv
    kernel = functools.partial(_verify_kernel, bv=bv, nv=nv, k1=K1)
    return pl.pallas_call(
        kernel,
        grid=(B, nv),
        in_specs=[
            pl.BlockSpec((1, K1, bv), lambda b, j: (b, 0, j)),
            pl.BlockSpec((1, K), lambda b, j: (b, 0), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1), lambda b, j: (b, 0), memory_space=pltpu.SMEM),
        ],
        out_specs=[
            pl.BlockSpec((1, 1), lambda b, j: (b, 0), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1), lambda b, j: (b, 0), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, K), lambda b, j: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, 1), jnp.int32),
            jax.ShapeDtypeStruct((B, 1), jnp.int32),
            jax.ShapeDtypeStruct((B, K), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((K1,), jnp.float32),
            pltpu.VMEM((K1,), jnp.int32),
            pltpu.VMEM((K1,), jnp.float32),
            pltpu.VMEM((K1,), jnp.float32),
        ],
        compiler_params=CompilerParams(dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(target_logits, draft_tokens.astype(jnp.int32), n_drafted.reshape(B, 1).astype(jnp.int32))
