"""Pallas TPU fused speculative-verification (greedy NAV) kernel.

The NAV step's post-processing is memory-bound on the target logits
[B, K+1, V] (V up to 262k padded): XLA's naive lowering reads the logits
once for argmax, once for log-softmax, and once for the draft-token gather.
This kernel fuses all three into ONE pass over the vocabulary:

    per (lane, vocab-block): running (max, argmax, logsumexp) per position
    + gather of each draft token's logit when its id falls in the block;
    final block → n_accepted, correction token, draft-token log-probs.

Grid: (B, num_vocab_blocks), vocab dimension "arbitrary" (sequential) with
running state in VMEM scratch.  K+1 ≤ 16 positions; vocab blocks of 2048
keep the [K+1, BV] score tile ≤ 128 KB in VMEM.

Padding invariants (relied on by ``ops.spec_verify_batched``, which packs
ragged multi-session requests into one rectangular launch):

* rows with ``n_drafted = 0`` produce ``n_accepted = 0`` and touch nothing
  else — whole padding rows (zero logits, zero tokens) are inert;
* positions ``>= n_drafted`` never accept (the match is masked by
  ``pos < n_drafted``), and the correction index ``min(n_accepted, K)``
  never exceeds ``n_drafted``, so per-row padding columns beyond a
  session's real draft length cannot leak into its outputs;
* ``logp`` lanes at padded positions carry garbage by design — callers
  slice ``logp[:K_i]``.

``_fused_verify_kernel`` goes one step further than fusing the logits
post-processing: it fuses the TARGET FORWARD itself — paged flash-decode
attention over the session's KV block tables (the
``kernels/decode_attention`` PrefetchScalarGridSpec machinery) plus the
LM-head projection plus the accept/reject scan — so a K-token chain verify
is ONE kernel launch instead of attention-launch-then-verify-launch.  Grid
``(B, G + NV)``: steps ``t < G`` stream physical page ``bt[b, t]`` and
advance K+1 online-softmax states (one per query position, causal
per-position lengths), step ``t == G-1`` finalizes attention into a
``[K1, F]`` VMEM tile, and steps ``t >= G`` stream LM-head tiles
``W[:, (t-G)*bv : ...]``, form the logits tile in-VMEM (masking padded
vocab ids to ``NEG_INF``), and run the UNMODIFIED ``_verify_kernel`` update
on it.  Because every op/shape matches the unfused kernels exactly — same
``einsum`` tiles, same output-dtype round-trip, same blocked ``jnp.dot``,
same scan — the fused launch is bit-exact vs the
``paged_decode_attention`` → projection → ``spec_verify`` composition
(``tests/test_spec_verify_fused.py``).  The int8 variant dequantizes pages
in-VMEM exactly like ``paged_decode_attention_q8_pallas``.

``_tree_verify_kernel`` is the tree-NAV generalization: N packed tree nodes
verified against N+1 logits rows (row 0 = anchor, row 1+i = node i), where
node i is scored by its PARENT's row (``prow = parents + 1``) and acceptance
propagates along the packed ancestor mask ``anc[i, j]`` — accepted(i) =
∀j on root→i path: match(j).  The finalize step reduces to the deepest
accepted node (ties → smallest packed index), its depth, and the correction
token from that node's own row.  The same padding invariants hold with
``n_drafted`` replaced by ``n_nodes``: pad nodes never match, and real
nodes' ancestor sets contain only real nodes, so pad nodes cannot veto an
acceptance.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .._compat import CompilerParams

DEFAULT_BV = 2048
NEG_INF = -1e30


def _verify_kernel(
    logits_ref,  # [1, K1, BV] f32/bf16 target logits block
    tokens_ref,  # [1, K] i32 draft tokens (SMEM)
    nd_ref,  # [1, 1] i32 n_drafted (SMEM)
    nacc_ref,  # [1, 1] i32 out
    corr_ref,  # [1, 1] i32 out
    logp_ref,  # [1, K] f32 out — log P_target(draft token)
    m_scr,  # [K1] f32 running max
    arg_scr,  # [K1] i32 running argmax
    lse_scr,  # [K1] f32 running sum exp (shifted by m)
    tok_scr,  # [K1] f32 draft-token logits (position i holds logit of draft i)
    *,
    bv: int,
    nv: int,
    k1: int,
):
    vb = pl.program_id(1)

    @pl.when(vb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        arg_scr[...] = jnp.zeros_like(arg_scr)
        lse_scr[...] = jnp.zeros_like(lse_scr)
        tok_scr[...] = jnp.full_like(tok_scr, NEG_INF)

    s = logits_ref[0].astype(jnp.float32)  # [K1, BV]
    ids = vb * bv + jax.lax.broadcasted_iota(jnp.int32, (k1, bv), 1)
    blk_max = jnp.max(s, axis=-1)  # [K1]
    blk_arg = jnp.min(jnp.where(s == blk_max[:, None], ids, jnp.int32(2**30)), axis=-1)
    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, blk_max)
    lse_scr[...] = lse_scr[...] * jnp.exp(m_prev - m_new) + jnp.sum(jnp.exp(s - m_new[:, None]), axis=-1)
    arg_scr[...] = jnp.where(blk_max > m_prev, blk_arg, arg_scr[...])
    m_scr[...] = m_new
    # Gather draft-token logits owned by this block: position i's draft token
    # is tokens[i] and is verified against logits row i (row K is the bonus).
    K = k1 - 1
    tok_row = jnp.concatenate(
        [tokens_ref[0, :].reshape(K), jnp.full((1,), -1, jnp.int32)]
    )  # [K1]
    hit = ids == tok_row[:, None]  # [K1, BV]
    gathered = jnp.sum(jnp.where(hit, s, 0.0), axis=-1)
    tok_scr[...] = jnp.where(jnp.any(hit, axis=-1), gathered, tok_scr[...])

    @pl.when(vb == nv - 1)
    def _finalize():
        greedy = arg_scr[...]  # [K1]
        lse = m_scr[...] + jnp.log(jnp.maximum(lse_scr[...], 1e-30))
        n_d = nd_ref[0, 0]
        pos = jax.lax.broadcasted_iota(jnp.int32, (k1,), 0)
        match = jnp.logical_and(greedy == tok_row, pos < n_d)[:K]
        n_acc = jnp.sum(jnp.cumprod(match.astype(jnp.int32)))
        nacc_ref[0, 0] = n_acc
        corr_ref[0, 0] = jnp.sum(jnp.where(pos == jnp.minimum(n_acc, K), greedy, 0))
        logp_ref[0, :] = (tok_scr[...] - lse)[:K]


def _fused_verify_kernel(
    bt_ref,  # [B, G] i32 scalar-prefetch — physical page id per logical page
    len_ref,  # [B, K1] i32 scalar-prefetch — valid KV length per query position
    q_ref,  # [1, K1, H, hd] — query per draft position (row K = bonus)
    k_ref,  # [1, bs, H, hd] — physical page bt[b, min(t, G-1)]
    v_ref,  # [1, bs, H, hd]
    *rest,  # [quant: ks/kz/vs/vz [1, bs, H]] w [F, bv], tokens, nd, outs, scratch
    sm_scale: float,
    window: int,
    bs: int,
    ng: int,
    bv: int,
    nv: int,
    k1: int,
    v_true: int,
    quantized: bool,
):
    if quantized:
        ks_ref, kz_ref, vs_ref, vz_ref = rest[:4]
        rest = rest[4:]
    (
        w_ref,  # [F, bv] f32 LM-head tile (t - ng)
        tokens_ref,  # [1, K] i32 (SMEM)
        nd_ref,  # [1, 1] i32 (SMEM)
        nacc_ref,  # [1, 1] i32 out
        corr_ref,  # [1, 1] i32 out
        logp_ref,  # [1, K] f32 out
        m_att,  # [K1, H] f32 — attention running max per position
        l_att,  # [K1, H] f32
        acc_att,  # [K1, H, hd] f32
        o_scr,  # [K1, F] f32 — finalized attention outputs (F = H*hd)
        m_scr,  # [K1] f32 — verify running max
        arg_scr,  # [K1] i32
        lse_scr,  # [K1] f32
        tok_scr,  # [K1] f32
    ) = rest
    b, t = pl.program_id(0), pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        m_att[...] = jnp.full_like(m_att, NEG_INF)
        l_att[...] = jnp.zeros_like(l_att)
        acc_att[...] = jnp.zeros_like(acc_att)
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        arg_scr[...] = jnp.zeros_like(arg_scr)
        lse_scr[...] = jnp.zeros_like(lse_scr)
        tok_scr[...] = jnp.full_like(tok_scr, NEG_INF)

    # ---- Phase 1 (t < ng): paged flash-decode for K1 query positions. ----
    # Per position the ops/shapes mirror _paged_decode_kernel exactly (one
    # [H, hd] x [bs, H, hd] einsum per position) so phase-1 state is bitwise
    # what the unfused paged kernel would hold for the same (lane, page).
    @pl.when(t < ng)
    def _attend():
        if quantized:
            k = (k_ref[0].astype(jnp.float32) + 128.0) * ks_ref[0][..., None] + kz_ref[0][..., None]
            v = (v_ref[0].astype(jnp.float32) + 128.0) * vs_ref[0][..., None] + vz_ref[0][..., None]
        else:
            k = k_ref[0].astype(jnp.float32)  # [bs, H, hd]
            v = v_ref[0].astype(jnp.float32)
        k_pos = t * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
        for i in range(k1):
            q = q_ref[0, i].astype(jnp.float32)  # [H, hd]
            s = jnp.einsum("hd,khd->hk", q, k) * sm_scale  # [H, bs]
            length = len_ref[b, i]
            valid = k_pos < length
            valid = jnp.logical_and(valid, k_pos >= length - window)
            s = jnp.where(valid, s, NEG_INF)
            m_prev = m_att[i, :]
            m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[:, None])
            alpha = jnp.exp(m_prev - m_new)
            l_att[i, :] = alpha * l_att[i, :] + jnp.sum(p, axis=-1)
            acc_att[i, :, :] = acc_att[i, :, :] * alpha[:, None] + jnp.einsum("hk,khd->hd", p, v)
            m_att[i, :] = m_new

    @pl.when(t == ng - 1)
    def _finalize_attention():
        # Round-trip through the query dtype exactly like the unfused
        # kernel's o_ref cast, so downstream logits see identical values.
        for i in range(k1):
            denom = jnp.maximum(l_att[i, :], 1e-30)[:, None]
            o = (acc_att[i, :, :] / denom).astype(q_ref.dtype)  # [H, hd]
            o_scr[i, :] = o.astype(jnp.float32).reshape(-1)

    # ---- Phase 2 (t >= ng): LM-head tile + the _verify_kernel update. ----
    K = k1 - 1
    tok_row = jnp.concatenate(
        [tokens_ref[0, :].reshape(K), jnp.full((1,), -1, jnp.int32)]
    )  # [K1]

    @pl.when(t >= ng)
    def _verify():
        vb = t - ng
        s = jnp.dot(o_scr[...], w_ref[...])  # [K1, bv] f32
        ids = vb * bv + jax.lax.broadcasted_iota(jnp.int32, (k1, bv), 1)
        s = jnp.where(ids >= v_true, NEG_INF, s)  # vocab pad lanes are inert
        blk_max = jnp.max(s, axis=-1)  # [K1]
        blk_arg = jnp.min(jnp.where(s == blk_max[:, None], ids, jnp.int32(2**30)), axis=-1)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, blk_max)
        lse_scr[...] = lse_scr[...] * jnp.exp(m_prev - m_new) + jnp.sum(
            jnp.exp(s - m_new[:, None]), axis=-1
        )
        arg_scr[...] = jnp.where(blk_max > m_prev, blk_arg, arg_scr[...])
        m_scr[...] = m_new
        hit = ids == tok_row[:, None]  # [K1, bv]
        gathered = jnp.sum(jnp.where(hit, s, 0.0), axis=-1)
        tok_scr[...] = jnp.where(jnp.any(hit, axis=-1), gathered, tok_scr[...])

    @pl.when(t == ng + nv - 1)
    def _finalize():
        greedy = arg_scr[...]  # [K1]
        lse = m_scr[...] + jnp.log(jnp.maximum(lse_scr[...], 1e-30))
        n_d = nd_ref[0, 0]
        pos = jax.lax.broadcasted_iota(jnp.int32, (k1,), 0)
        match = jnp.logical_and(greedy == tok_row, pos < n_d)[:K]
        n_acc = jnp.sum(jnp.cumprod(match.astype(jnp.int32)))
        nacc_ref[0, 0] = n_acc
        corr_ref[0, 0] = jnp.sum(jnp.where(pos == jnp.minimum(n_acc, K), greedy, 0))
        logp_ref[0, :] = (tok_scr[...] - lse)[:K]


def spec_verify_fused_pallas(
    q: jax.Array,  # [B, K+1, H, hd] — per-position queries (GQA-expanded pages)
    k_pages: jax.Array,  # [P, bs, H, hd] (int8 when quant is given)
    v_pages: jax.Array,
    w: jax.Array,  # [H*hd, Vp] f32 LM head, Vp % block_v == 0 (zero-padded)
    block_tables: jax.Array,  # [B, G] i32 physical page ids
    lengths: jax.Array,  # [B, K+1] i32 valid KV length per query position
    draft_tokens: jax.Array,  # [B, K] i32
    n_drafted: jax.Array,  # [B] i32
    *,
    v_true: int,
    window: int = 1 << 30,
    block_v: int = DEFAULT_BV,
    quant=None,  # (k_scale, k_zero, v_scale, v_zero), each [P, bs, H] f32
    interpret: bool = False,
):
    """One-launch chain verify: paged attention + LM head + NAV scan fused.

    Returns ``(n_accepted [B,1], correction [B,1], logp [B,K])`` — the same
    contract as ``spec_verify_pallas`` — from queries + paged KV + LM head
    instead of precomputed logits.  Bit-exact vs the unfused composition by
    construction (see module docstring).
    """
    B, K1, H, hd = q.shape
    P, bs, Hk, _ = k_pages.shape
    if Hk != H:
        raise ValueError(f"pages must be GQA-expanded: {Hk} heads vs {H} queries")
    if K1 > 128:
        raise ValueError(f"K+1={K1} exceeds the [K1] VMEM scratch budget (max 128)")
    F, Vp = w.shape
    if F != H * hd:
        raise ValueError(f"LM head rows {F} != H*hd = {H * hd}")
    bv = min(block_v, Vp)
    if Vp % bv:
        raise ValueError(f"Vp={Vp} must be divisible by block_v={bv}")
    nv = Vp // bv
    G = block_tables.shape[1]
    K = K1 - 1
    sm_scale = 1.0 / math.sqrt(hd)
    kernel = functools.partial(
        _fused_verify_kernel,
        sm_scale=sm_scale,
        window=int(window),
        bs=bs,
        ng=G,
        bv=bv,
        nv=nv,
        k1=K1,
        v_true=int(v_true),
        quantized=quant is not None,
    )
    page_ix = lambda b, t, bt, ln: (bt[b, jnp.minimum(t, G - 1)], 0, 0, 0)  # noqa: E731
    param_ix = lambda b, t, bt, ln: (bt[b, jnp.minimum(t, G - 1)], 0, 0)  # noqa: E731
    in_specs = [
        pl.BlockSpec((1, K1, H, hd), lambda b, t, bt, ln: (b, 0, 0, 0)),
        pl.BlockSpec((1, bs, H, hd), page_ix),
        pl.BlockSpec((1, bs, H, hd), page_ix),
    ]
    operands = [q, k_pages, v_pages]
    if quant is not None:
        in_specs += [pl.BlockSpec((1, bs, H), param_ix)] * 4
        operands += [p.astype(jnp.float32) for p in quant]
    in_specs += [
        pl.BlockSpec((F, bv), lambda b, t, bt, ln: (0, jnp.maximum(t - G, 0))),
        pl.BlockSpec((1, K), lambda b, t, bt, ln: (b, 0), memory_space=pltpu.SMEM),
        pl.BlockSpec((1, 1), lambda b, t, bt, ln: (b, 0), memory_space=pltpu.SMEM),
    ]
    operands += [
        w.astype(jnp.float32),
        draft_tokens.astype(jnp.int32),
        n_drafted.reshape(B, 1).astype(jnp.int32),
    ]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # block_tables, per-position lengths
        grid=(B, G + nv),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, 1), lambda b, t, bt, ln: (b, 0), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1), lambda b, t, bt, ln: (b, 0), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, K), lambda b, t, bt, ln: (b, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((K1, H), jnp.float32),
            pltpu.VMEM((K1, H), jnp.float32),
            pltpu.VMEM((K1, H, hd), jnp.float32),
            pltpu.VMEM((K1, F), jnp.float32),
            pltpu.VMEM((K1,), jnp.float32),
            pltpu.VMEM((K1,), jnp.int32),
            pltpu.VMEM((K1,), jnp.float32),
            pltpu.VMEM((K1,), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, 1), jnp.int32),
            jax.ShapeDtypeStruct((B, 1), jnp.int32),
            jax.ShapeDtypeStruct((B, K), jnp.float32),
        ],
        compiler_params=CompilerParams(dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(
        block_tables.astype(jnp.int32),
        lengths.astype(jnp.int32),
        *operands,
    )


def _tree_verify_kernel(
    logits_ref,  # [1, N1, BV] f32/bf16 target logits block (row 0 = anchor)
    tokens_ref,  # [1, N] i32 packed node tokens (SMEM)
    prow_ref,  # [1, N] i32 verify row per node = parents + 1 (SMEM)
    depth_ref,  # [1, N] i32 1-based node depth (SMEM)
    nn_ref,  # [1, 1] i32 n_nodes (SMEM)
    anc_ref,  # [1, N, N] i32 packed ancestor mask (anc[i,j]=1: j on root→i path)
    nacc_ref,  # [1, 1] i32 out — depth of deepest accepted node
    best_ref,  # [1, 1] i32 out — packed index of that node (-1 if none)
    corr_ref,  # [1, 1] i32 out — correction/bonus token
    logp_ref,  # [1, N] f32 out — log P_target(node token) at its verify row
    m_scr,  # [N1] f32 running max
    arg_scr,  # [N1] i32 running argmax
    lse_scr,  # [N1] f32 running sum exp (shifted by m)
    tok_scr,  # [N] f32 node-token logits gathered at each node's verify row
    *,
    bv: int,
    nv: int,
    n1: int,
):
    vb = pl.program_id(1)
    N = n1 - 1

    @pl.when(vb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        arg_scr[...] = jnp.zeros_like(arg_scr)
        lse_scr[...] = jnp.zeros_like(lse_scr)
        tok_scr[...] = jnp.full_like(tok_scr, NEG_INF)

    s = logits_ref[0].astype(jnp.float32)  # [N1, BV]
    ids1 = vb * bv + jax.lax.broadcasted_iota(jnp.int32, (n1, bv), 1)
    blk_max = jnp.max(s, axis=-1)  # [N1]
    blk_arg = jnp.min(jnp.where(s == blk_max[:, None], ids1, jnp.int32(2**30)), axis=-1)
    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, blk_max)
    lse_scr[...] = lse_scr[...] * jnp.exp(m_prev - m_new) + jnp.sum(jnp.exp(s - m_new[:, None]), axis=-1)
    arg_scr[...] = jnp.where(blk_max > m_prev, blk_arg, arg_scr[...])
    m_scr[...] = m_new
    # Gather each node's token logit from its VERIFY row (unlike the chain
    # kernel, node i is scored by row prow[i], not row i): a one-hot matmul
    # re-indexes the [N1, BV] tile to [N, BV] before the in-block id match.
    tok_row = tokens_ref[0, :].reshape(N)  # [N]
    prow = prow_ref[0, :].reshape(N)  # [N]
    row_ids = jax.lax.broadcasted_iota(jnp.int32, (N, n1), 1)
    onehot = (row_ids == prow[:, None]).astype(jnp.float32)  # [N, N1]
    s_at = jnp.dot(onehot, s, preferred_element_type=jnp.float32)  # [N, BV]
    ids = vb * bv + jax.lax.broadcasted_iota(jnp.int32, (N, bv), 1)
    hit = ids == tok_row[:, None]  # [N, BV]
    gathered = jnp.sum(jnp.where(hit, s_at, 0.0), axis=-1)
    tok_scr[...] = jnp.where(jnp.any(hit, axis=-1), gathered, tok_scr[...])

    @pl.when(vb == nv - 1)
    def _finalize():
        greedy = arg_scr[...]  # [N1]
        lse = m_scr[...] + jnp.log(jnp.maximum(lse_scr[...], 1e-30))
        n_d = nn_ref[0, 0]
        depth = depth_ref[0, :].reshape(N)
        oh = row_ids == prow[:, None]  # [N, N1]
        g_at = jnp.sum(jnp.where(oh, greedy[None, :], 0), axis=-1)  # [N]
        lse_at = jnp.sum(jnp.where(oh, lse[None, :], 0.0), axis=-1)
        pos = jax.lax.broadcasted_iota(jnp.int32, (N,), 0)
        valid = pos < n_d
        match = jnp.logical_and(g_at == tok_row, valid)
        anc = anc_ref[0] != 0  # [N, N]
        # accepted[i] = all nodes on root→i path match (anc[i,i] covers i).
        accepted = jnp.logical_and(jnp.all(jnp.logical_or(match[None, :], ~anc), axis=-1), valid)
        acc_depth = jnp.where(accepted, depth, 0)
        n_acc = jnp.max(acc_depth)
        best = jnp.min(jnp.where(jnp.logical_and(accepted, acc_depth == n_acc), pos, jnp.int32(2**30)))
        best = jnp.where(n_acc > 0, best, -1)
        best_row = jnp.where(n_acc > 0, best + 1, 0)
        ids_n1 = jax.lax.broadcasted_iota(jnp.int32, (n1,), 0)
        nacc_ref[0, 0] = n_acc
        best_ref[0, 0] = best
        corr_ref[0, 0] = jnp.sum(jnp.where(ids_n1 == best_row, greedy, 0))
        logp_ref[0, :] = tok_scr[...] - lse_at


def spec_verify_tree_pallas(
    target_logits: jax.Array,  # [B, N+1, V] — row 0 anchor, row 1+i = node i
    tokens: jax.Array,  # [B, N] i32
    prow: jax.Array,  # [B, N] i32 (parents + 1)
    depth: jax.Array,  # [B, N] i32 (1-based)
    anc: jax.Array,  # [B, N, N] i32/bool packed ancestor mask
    n_nodes: jax.Array,  # [B] i32
    *,
    block_v: int = DEFAULT_BV,
    interpret: bool = False,
):
    B, N1, V = target_logits.shape
    N = N1 - 1
    if N < 1:
        raise ValueError("tree verification needs at least one node")
    if N1 > 128:
        raise ValueError(f"N+1={N1} exceeds the [N1] VMEM scratch budget (max 128)")
    bv = min(block_v, V)
    if V % bv:
        raise ValueError(f"V={V} must be divisible by block_v={bv}")
    nv = V // bv
    kernel = functools.partial(_tree_verify_kernel, bv=bv, nv=nv, n1=N1)
    return pl.pallas_call(
        kernel,
        grid=(B, nv),
        in_specs=[
            pl.BlockSpec((1, N1, bv), lambda b, j: (b, 0, j)),
            pl.BlockSpec((1, N), lambda b, j: (b, 0), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, N), lambda b, j: (b, 0), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, N), lambda b, j: (b, 0), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1), lambda b, j: (b, 0), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, N, N), lambda b, j: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1), lambda b, j: (b, 0), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1), lambda b, j: (b, 0), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1), lambda b, j: (b, 0), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, N), lambda b, j: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, 1), jnp.int32),
            jax.ShapeDtypeStruct((B, 1), jnp.int32),
            jax.ShapeDtypeStruct((B, 1), jnp.int32),
            jax.ShapeDtypeStruct((B, N), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((N1,), jnp.float32),
            pltpu.VMEM((N1,), jnp.int32),
            pltpu.VMEM((N1,), jnp.float32),
            pltpu.VMEM((N,), jnp.float32),
        ],
        compiler_params=CompilerParams(dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(
        target_logits,
        tokens.astype(jnp.int32),
        prow.astype(jnp.int32),
        depth.astype(jnp.int32),
        n_nodes.reshape(B, 1).astype(jnp.int32),
        anc.astype(jnp.int32),
    )


def spec_verify_pallas(
    target_logits: jax.Array,  # [B, K+1, V]
    draft_tokens: jax.Array,  # [B, K] i32
    n_drafted: jax.Array,  # [B] i32
    *,
    block_v: int = DEFAULT_BV,
    interpret: bool = False,
):
    B, K1, V = target_logits.shape
    K = K1 - 1
    if K1 > 128:
        raise ValueError(f"K+1={K1} exceeds the [K1] VMEM scratch budget (max 128)")
    bv = min(block_v, V)
    if V % bv:
        raise ValueError(f"V={V} must be divisible by block_v={bv}")
    nv = V // bv
    kernel = functools.partial(_verify_kernel, bv=bv, nv=nv, k1=K1)
    return pl.pallas_call(
        kernel,
        grid=(B, nv),
        in_specs=[
            pl.BlockSpec((1, K1, bv), lambda b, j: (b, 0, j)),
            pl.BlockSpec((1, K), lambda b, j: (b, 0), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1), lambda b, j: (b, 0), memory_space=pltpu.SMEM),
        ],
        out_specs=[
            pl.BlockSpec((1, 1), lambda b, j: (b, 0), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1), lambda b, j: (b, 0), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, K), lambda b, j: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, 1), jnp.int32),
            jax.ShapeDtypeStruct((B, 1), jnp.int32),
            jax.ShapeDtypeStruct((B, K), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((K1,), jnp.float32),
            pltpu.VMEM((K1,), jnp.int32),
            pltpu.VMEM((K1,), jnp.float32),
            pltpu.VMEM((K1,), jnp.float32),
        ],
        compiler_params=CompilerParams(dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(target_logits, draft_tokens.astype(jnp.int32), n_drafted.reshape(B, 1).astype(jnp.int32))
