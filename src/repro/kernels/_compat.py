"""Version tolerance for the Pallas TPU API surface.

jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams`` across
0.4.x/0.5.x; the kernels target the new name and fall back to the old one so
the repo runs on whichever toolchain the container bakes in.
"""

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(pltpu, "TPUCompilerParams")

__all__ = ["CompilerParams"]
