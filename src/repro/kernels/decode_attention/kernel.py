"""Pallas TPU decode attention (flash-decode): one query vs a long KV cache.

The serving hot path for ``decode_32k`` / ``long_500k``: a single new token's
query attends over S cached keys.  Grid: (batch, num_kv_blocks) with the kv
dimension "arbitrary" so online-softmax state (m, l, acc — per head) lives in
VMEM scratch across kv blocks.  KV blocks of [BK, hd] per head stream through
VMEM; per-lane valid lengths mask dead slots, and a sliding window bounds the
live region for local-attention layers.

Working set per step: H·hd (q) + 2·BK·H·hd (k,v) + H·BK (scores) floats —
BK=512, H≤64, hd≤256 stays well under VMEM.

**Paged variant** (``paged_decode_attention_pallas``): the KV cache lives in
a global page pool (``models/paged_kv.py``) instead of one contiguous buffer
per lane.  The grid stays (batch, pages-per-sequence), but the kv BlockSpec's
index map reads the *block table* — scalar-prefetched via
``pltpu.PrefetchScalarGridSpec`` so page ids are known before the kernel body
runs — to DMA physical page ``table[b, g]`` where the flat kernel would load
contiguous block ``g``.  With the page size matching the flat kernel's
``block_k``, the two kernels stream identical values in identical order, so
their outputs are bit-exact (pinned by ``tests/test_paged_attention.py``).
Pad table entries must hold valid page ids (the pool pads with its
zero-filled sentinel page); their positions sit past ``lengths`` and are
masked like any dead slot.

**Int8 variant** (``paged_decode_attention_q8_pallas``): pages carry int8
payload plus per-(slot, head) float32 ``scale``/``zero`` (affine over
``head_dim``; ``models/paged_kv.py``).  The scale/zero pages ride the same
block-table index map as the payload, and the kernel dequantizes in VMEM —
``x_hat = (q + 128) * scale + zero`` — before the identical online-softmax
math, so HBM traffic drops to ~1/4 + params while the arithmetic matches
the fp32 kernel on the dequantized values bit-for-bit.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .._compat import CompilerParams

DEFAULT_BK = 512
NEG_INF = -1e30


def _decode_kernel(
    len_ref,  # [1, 1] i32 — valid KV length for this lane
    q_ref,  # [1, H, hd]
    k_ref,  # [1, BK, H, hd]
    v_ref,  # [1, BK, H, hd]
    o_ref,  # [1, H, hd]
    m_scr,  # [H] f32
    l_scr,  # [H] f32
    acc_scr,  # [H, hd] f32
    *,
    sm_scale: float,
    window: int,
    bk: int,
    nk: int,
):
    kb = pl.program_id(1)

    @pl.when(kb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)  # [H, hd]
    k = k_ref[0].astype(jnp.float32)  # [BK, H, hd]
    v = v_ref[0].astype(jnp.float32)
    s = jnp.einsum("hd,khd->hk", q, k) * sm_scale  # [H, BK]
    length = len_ref[0, 0]
    k_pos = kb * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)  # [1, BK]
    valid = k_pos < length
    valid = jnp.logical_and(valid, k_pos >= length - window)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=-1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jnp.einsum("hk,khd->hd", p, v)
    m_scr[...] = m_new

    @pl.when(kb == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def decode_attention_pallas(
    q: jax.Array,  # [B, H, hd] — single-position queries
    k_cache: jax.Array,  # [B, S, H, hd]  (GQA-expanded by the wrapper)
    v_cache: jax.Array,
    lengths: jax.Array,  # [B] i32 valid prefix per lane
    *,
    window: int = 1 << 30,
    block_k: int = DEFAULT_BK,
    interpret: bool = False,
) -> jax.Array:
    B, S, H, hd = k_cache.shape
    bk = min(block_k, S)
    if S % bk:
        raise ValueError(f"S={S} must be divisible by block_k={bk}")
    nk = S // bk
    sm_scale = 1.0 / math.sqrt(hd)
    kernel = functools.partial(_decode_kernel, sm_scale=sm_scale, window=int(window), bk=bk, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=(B, nk),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, j: (b, 0), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, H, hd), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, bk, H, hd), lambda b, j: (b, j, 0, 0)),
            pl.BlockSpec((1, bk, H, hd), lambda b, j: (b, j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, H, hd), lambda b, j: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((H,), jnp.float32),
            pltpu.VMEM((H,), jnp.float32),
            pltpu.VMEM((H, hd), jnp.float32),
        ],
        compiler_params=CompilerParams(dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(lengths.reshape(B, 1).astype(jnp.int32), q, k_cache, v_cache)


def _paged_decode_kernel(
    bt_ref,  # [B, G] i32 scalar-prefetch — physical page id per logical page
    len_ref,  # [B] i32 scalar-prefetch — valid KV length per lane
    q_ref,  # [1, H, hd]
    k_ref,  # [1, bs, H, hd] — physical page bt[b, g]
    v_ref,  # [1, bs, H, hd]
    o_ref,  # [1, H, hd]
    m_scr,  # [H] f32
    l_scr,  # [H] f32
    acc_scr,  # [H, hd] f32
    *,
    sm_scale: float,
    window: int,
    bs: int,
    ng: int,
):
    b, g = pl.program_id(0), pl.program_id(1)

    @pl.when(g == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)  # [H, hd]
    k = k_ref[0].astype(jnp.float32)  # [bs, H, hd]
    v = v_ref[0].astype(jnp.float32)
    s = jnp.einsum("hd,khd->hk", q, k) * sm_scale  # [H, bs]
    length = len_ref[b]
    # Logical positions: page g covers [g*bs, (g+1)*bs) regardless of which
    # physical page backs it — the table indirection is purely in the DMA.
    k_pos = g * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
    valid = k_pos < length
    valid = jnp.logical_and(valid, k_pos >= length - window)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=-1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jnp.einsum("hk,khd->hd", p, v)
    m_scr[...] = m_new

    @pl.when(g == ng - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def paged_decode_attention_pallas(
    q: jax.Array,  # [B, H, hd] — single-position queries
    k_pages: jax.Array,  # [P, bs, H, hd]  (GQA-expanded by the wrapper)
    v_pages: jax.Array,
    block_tables: jax.Array,  # [B, G] i32 physical page ids (pads = any valid id)
    lengths: jax.Array,  # [B] i32 valid prefix per lane
    *,
    window: int = 1 << 30,
    interpret: bool = False,
) -> jax.Array:
    """Flash-decode over a paged KV pool: block-table gather via scalar prefetch.

    Grid (B, G); kv page ``g`` of lane ``b`` streams from physical page
    ``block_tables[b, g]`` — the BlockSpec index map reads the prefetched
    table, so the DMA engine chases the indirection, not the kernel body.
    """
    B, H, hd = q.shape
    P, bs, Hk, _ = k_pages.shape
    if Hk != H:
        raise ValueError(f"pages must be GQA-expanded: {Hk} heads vs {H} queries")
    G = block_tables.shape[1]
    sm_scale = 1.0 / math.sqrt(hd)
    kernel = functools.partial(
        _paged_decode_kernel, sm_scale=sm_scale, window=int(window), bs=bs, ng=G
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # block_tables, lengths
        grid=(B, G),
        in_specs=[
            pl.BlockSpec((1, H, hd), lambda b, g, bt, ln: (b, 0, 0)),
            pl.BlockSpec((1, bs, H, hd), lambda b, g, bt, ln: (bt[b, g], 0, 0, 0)),
            pl.BlockSpec((1, bs, H, hd), lambda b, g, bt, ln: (bt[b, g], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, H, hd), lambda b, g, bt, ln: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((H,), jnp.float32),
            pltpu.VMEM((H,), jnp.float32),
            pltpu.VMEM((H, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, hd), q.dtype),
        compiler_params=CompilerParams(dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), lengths.astype(jnp.int32), q, k_pages, v_pages)


def _paged_decode_q8_kernel(
    bt_ref,  # [B, G] i32 scalar-prefetch
    len_ref,  # [B] i32 scalar-prefetch
    q_ref,  # [1, H, hd]
    k_ref,  # [1, bs, H, hd] int8 — physical page bt[b, g]
    v_ref,  # [1, bs, H, hd] int8
    ks_ref,  # [1, bs, H] f32 scale
    kz_ref,  # [1, bs, H] f32 zero
    vs_ref,  # [1, bs, H] f32
    vz_ref,  # [1, bs, H] f32
    o_ref,  # [1, H, hd]
    m_scr,  # [H] f32
    l_scr,  # [H] f32
    acc_scr,  # [H, hd] f32
    *,
    sm_scale: float,
    window: int,
    bs: int,
    ng: int,
):
    b, g = pl.program_id(0), pl.program_id(1)

    @pl.when(g == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)  # [H, hd]
    # In-VMEM affine dequant: x_hat = (int8 + 128) * scale + zero, params
    # broadcast over head_dim.  Matches PagedKVPool.dequantize_kv exactly.
    k = (k_ref[0].astype(jnp.float32) + 128.0) * ks_ref[0][..., None] + kz_ref[0][..., None]
    v = (v_ref[0].astype(jnp.float32) + 128.0) * vs_ref[0][..., None] + vz_ref[0][..., None]
    s = jnp.einsum("hd,khd->hk", q, k) * sm_scale  # [H, bs]
    length = len_ref[b]
    k_pos = g * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
    valid = k_pos < length
    valid = jnp.logical_and(valid, k_pos >= length - window)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=-1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jnp.einsum("hk,khd->hd", p, v)
    m_scr[...] = m_new

    @pl.when(g == ng - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def paged_decode_attention_q8_pallas(
    q: jax.Array,  # [B, H, hd]
    k_pages: jax.Array,  # [P, bs, H, hd] int8  (GQA-expanded by the wrapper)
    v_pages: jax.Array,
    k_scale: jax.Array,  # [P, bs, H] f32 — affine params over head_dim
    k_zero: jax.Array,
    v_scale: jax.Array,
    v_zero: jax.Array,
    block_tables: jax.Array,  # [B, G] i32 physical page ids
    lengths: jax.Array,  # [B] i32
    *,
    window: int = 1 << 30,
    interpret: bool = False,
) -> jax.Array:
    """Paged flash-decode over int8 pages with in-kernel affine dequant.

    Same grid and DMA indirection as ``paged_decode_attention_pallas``; the
    four quant-param planes ride the identical ``bt[b, g]`` index map so a
    page's payload and parameters always arrive together.
    """
    B, H, hd = q.shape
    P, bs, Hk, _ = k_pages.shape
    if Hk != H:
        raise ValueError(f"pages must be GQA-expanded: {Hk} heads vs {H} queries")
    if k_pages.dtype != jnp.int8:
        raise TypeError(f"q8 entry needs int8 pages, got {k_pages.dtype}")
    G = block_tables.shape[1]
    sm_scale = 1.0 / math.sqrt(hd)
    kernel = functools.partial(
        _paged_decode_q8_kernel, sm_scale=sm_scale, window=int(window), bs=bs, ng=G
    )
    page_spec = pl.BlockSpec((1, bs, H, hd), lambda b, g, bt, ln: (bt[b, g], 0, 0, 0))
    param_spec = pl.BlockSpec((1, bs, H), lambda b, g, bt, ln: (bt[b, g], 0, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # block_tables, lengths
        grid=(B, G),
        in_specs=[
            pl.BlockSpec((1, H, hd), lambda b, g, bt, ln: (b, 0, 0)),
            page_spec,
            page_spec,
            param_spec,
            param_spec,
            param_spec,
            param_spec,
        ],
        out_specs=pl.BlockSpec((1, H, hd), lambda b, g, bt, ln: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((H,), jnp.float32),
            pltpu.VMEM((H,), jnp.float32),
            pltpu.VMEM((H, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, hd), q.dtype),
        compiler_params=CompilerParams(dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(
        block_tables.astype(jnp.int32),
        lengths.astype(jnp.int32),
        q,
        k_pages,
        v_pages,
        k_scale.astype(jnp.float32),
        k_zero.astype(jnp.float32),
        v_scale.astype(jnp.float32),
        v_zero.astype(jnp.float32),
    )
