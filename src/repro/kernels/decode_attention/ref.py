"""Pure-jnp oracles for single-position decode attention (flat and paged).

The paged oracle gathers physical pages through the block table into the
flat layout and reuses the flat oracle verbatim, so flat-vs-paged parity is
bit-exact *by construction*: identical values flow through identical
arithmetic (`tests/test_paged_attention.py` pins this with
``np.testing.assert_array_equal``).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def decode_attention_ref(
    q: jax.Array,  # [B, H, hd]
    k_cache: jax.Array,  # [B, S, H, hd]
    v_cache: jax.Array,
    lengths: jax.Array,  # [B]
    *,
    window: int = 1 << 30,
) -> jax.Array:
    B, S, H, hd = k_cache.shape
    s = jnp.einsum("bhd,bkhd->bhk", q.astype(jnp.float32), k_cache.astype(jnp.float32))
    s = s / math.sqrt(hd)
    k_pos = jnp.arange(S)[None, :]
    valid = jnp.logical_and(k_pos < lengths[:, None], k_pos >= lengths[:, None] - window)
    s = jnp.where(valid[:, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhk,bkhd->bhd", p, v_cache.astype(jnp.float32)).astype(q.dtype)


def paged_gather(pages: jax.Array, block_tables: jax.Array) -> jax.Array:
    """Assemble the flat cache view from physical pages.

    ``pages: [P, bs, H, hd]`` and ``block_tables: [B, G]`` (int32 physical
    page ids; logical position ``p`` of lane ``b`` lives in page
    ``block_tables[b, p // bs]`` at slot ``p % bs``) gather to
    ``[B, G*bs, H, hd]``.  Pad table entries may hold any *valid* page id
    (the pool pads with 0): their positions sit past ``lengths`` and are
    masked by the attention oracle/kernel.
    """
    B, G = block_tables.shape
    P, bs, H, hd = pages.shape
    flat = jnp.take(pages, block_tables.reshape(-1), axis=0)  # [B*G, bs, H, hd]
    return flat.reshape(B, G * bs, H, hd)


def dequantize_pages(pages: jax.Array, scale: jax.Array, zero: jax.Array) -> jax.Array:
    """Affine-dequantize int8 pages to float32.

    ``pages: [P, bs, H, hd]`` int8, ``scale/zero: [P, bs, H]`` f32 →
    ``x_hat = (q + 128) * scale + zero``, the exact inverse the pool's
    ``write`` quantizer targets (``models/paged_kv.py``) and the arithmetic
    the q8 kernel performs in VMEM — so kernel-vs-ref parity on int8 pages
    is bit-exact, while int8-vs-fp32 parity is bounded by ``scale / 2`` per
    element.
    """
    return (pages.astype(jnp.float32) + 128.0) * scale[..., None] + zero[..., None]


def paged_decode_attention_q8_ref(
    q: jax.Array,  # [B, H, hd]
    k_pages: jax.Array,  # [P, bs, H, hd] int8
    v_pages: jax.Array,
    k_scale: jax.Array,  # [P, bs, H] f32
    k_zero: jax.Array,
    v_scale: jax.Array,
    v_zero: jax.Array,
    block_tables: jax.Array,  # [B, G]
    lengths: jax.Array,  # [B]
    *,
    window: int = 1 << 30,
) -> jax.Array:
    """Int8 paged oracle: dequantize pages, then the fp32 paged oracle."""
    k = dequantize_pages(k_pages, k_scale, k_zero)
    v = dequantize_pages(v_pages, v_scale, v_zero)
    return paged_decode_attention_ref(q, k, v, block_tables, lengths, window=window)


def paged_decode_attention_ref(
    q: jax.Array,  # [B, H, hd]
    k_pages: jax.Array,  # [P, bs, H, hd]
    v_pages: jax.Array,
    block_tables: jax.Array,  # [B, G] int32 physical page ids
    lengths: jax.Array,  # [B]
    *,
    window: int = 1 << 30,
) -> jax.Array:
    """Paged oracle: page gather + the flat oracle — bit-exact vs flat."""
    k = paged_gather(k_pages, block_tables)
    v = paged_gather(v_pages, block_tables)
    return decode_attention_ref(q, k, v, lengths, window=window)
