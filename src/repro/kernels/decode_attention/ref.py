"""Pure-jnp oracle for single-position decode attention."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def decode_attention_ref(
    q: jax.Array,  # [B, H, hd]
    k_cache: jax.Array,  # [B, S, H, hd]
    v_cache: jax.Array,
    lengths: jax.Array,  # [B]
    *,
    window: int = 1 << 30,
) -> jax.Array:
    B, S, H, hd = k_cache.shape
    s = jnp.einsum("bhd,bkhd->bhk", q.astype(jnp.float32), k_cache.astype(jnp.float32))
    s = s / math.sqrt(hd)
    k_pos = jnp.arange(S)[None, :]
    valid = jnp.logical_and(k_pos < lengths[:, None], k_pos >= lengths[:, None] - window)
    s = jnp.where(valid[:, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhk,bkhd->bhd", p, v_cache.astype(jnp.float32)).astype(q.dtype)
