"""Jit'd wrapper for decode attention (GQA expansion + impl dispatch)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import decode_attention_pallas
from .ref import decode_attention_ref


@functools.partial(jax.jit, static_argnames=("window", "impl", "block_k"))
def decode_attention(
    q: jax.Array,  # [B, H, hd]
    k_cache: jax.Array,  # [B, S, Hkv, hd]
    v_cache: jax.Array,
    lengths: jax.Array,  # [B]
    *,
    window: int = 1 << 30,
    impl: str = "interpret",
    block_k: int = 512,
) -> jax.Array:
    H = q.shape[1]
    n_kv = k_cache.shape[2]
    if n_kv != H:
        k_cache = jnp.repeat(k_cache, H // n_kv, axis=2)
        v_cache = jnp.repeat(v_cache, H // n_kv, axis=2)
    if impl == "ref":
        return decode_attention_ref(q, k_cache, v_cache, lengths, window=window)
    return decode_attention_pallas(
        q, k_cache, v_cache, lengths, window=window, block_k=block_k, interpret=(impl == "interpret")
    )
