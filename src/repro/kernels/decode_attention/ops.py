"""Jit'd wrappers for decode attention (GQA expansion + impl dispatch).

Two entries share one dispatch convention (``impl``: ``'ref'`` pure-JAX
oracle, ``'interpret'`` Pallas interpret mode for CPU, ``'pallas'`` compiled
TPU):

* ``decode_attention`` — flat contiguous cache ``[B, S, Hkv, hd]``;
* ``paged_decode_attention`` — global page pool ``[P, bs, Hkv, hd]`` +
  per-lane block tables (``models/paged_kv.py``), the serving layout where
  sessions share prefix pages copy-on-write.  Ragged python block tables are
  padded through ``kernels.spec_verify.pad_block_tables`` (the same pow2
  bucketing as the batched NAV entries, pad id 0 = valid page, masked by
  ``lengths``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..spec_verify.ops import pad_block_tables
from .kernel import decode_attention_pallas, paged_decode_attention_pallas
from .ref import decode_attention_ref, paged_decode_attention_ref


@functools.partial(jax.jit, static_argnames=("window", "impl", "block_k"))
def decode_attention(
    q: jax.Array,  # [B, H, hd]
    k_cache: jax.Array,  # [B, S, Hkv, hd]
    v_cache: jax.Array,
    lengths: jax.Array,  # [B]
    *,
    window: int = 1 << 30,
    impl: str = "interpret",
    block_k: int = 512,
) -> jax.Array:
    """Single-position decode attention over a flat contiguous KV cache."""
    H = q.shape[1]
    n_kv = k_cache.shape[2]
    if n_kv != H:
        k_cache = jnp.repeat(k_cache, H // n_kv, axis=2)
        v_cache = jnp.repeat(v_cache, H // n_kv, axis=2)
    if impl == "ref":
        return decode_attention_ref(q, k_cache, v_cache, lengths, window=window)
    return decode_attention_pallas(
        q, k_cache, v_cache, lengths, window=window, block_k=block_k, interpret=(impl == "interpret")
    )


@functools.partial(jax.jit, static_argnames=("window", "impl"))
def _paged_dispatch(q, k_pages, v_pages, block_tables, lengths, *, window, impl):
    H = q.shape[1]
    n_kv = k_pages.shape[2]
    if n_kv != H:
        k_pages = jnp.repeat(k_pages, H // n_kv, axis=2)
        v_pages = jnp.repeat(v_pages, H // n_kv, axis=2)
    if impl == "ref":
        return paged_decode_attention_ref(q, k_pages, v_pages, block_tables, lengths, window=window)
    return paged_decode_attention_pallas(
        q, k_pages, v_pages, block_tables, lengths, window=window, interpret=(impl == "interpret")
    )


def paged_decode_attention(
    q: jax.Array,  # [B, H, hd]
    k_pages: jax.Array,  # [P, bs, Hkv, hd]
    v_pages: jax.Array,
    block_tables,  # [B, G] int32 array, or B ragged python page-id lists
    lengths: jax.Array,  # [B]
    *,
    window: int = 1 << 30,
    impl: str = "interpret",
    bucket: bool = True,
) -> jax.Array:
    """Single-position decode attention gathered through KV block tables.

    ``block_tables`` may be a rectangular ``[B, G]`` int32 array (e.g. from
    ``PagedKVPool.table(sid, pad_to=G)``) or ragged per-lane page-id lists,
    which are padded here with the serving bucketing (``pad_block_tables``).
    Bit-exact vs the flat entry on the same logical cache: ``impl='ref'``
    by construction (page gather + flat oracle), Pallas impls by streaming
    pages in the flat kernel's block order (``tests/test_paged_attention.py``).
    """
    if isinstance(block_tables, (list, tuple)):
        block_tables = pad_block_tables(block_tables, batch_pad=len(block_tables), bucket=bucket)
    return _paged_dispatch(
        q,
        k_pages,
        v_pages,
        jnp.asarray(block_tables, jnp.int32),
        jnp.asarray(lengths, jnp.int32),
        window=window,
        impl=impl,
    )
