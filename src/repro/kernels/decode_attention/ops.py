"""Jit'd wrappers for decode attention (GQA expansion + impl dispatch).

Two entries share one dispatch convention (``impl``: ``'ref'`` pure-JAX
oracle, ``'interpret'`` Pallas interpret mode for CPU, ``'pallas'`` compiled
TPU):

* ``decode_attention`` — flat contiguous cache ``[B, S, Hkv, hd]``;
* ``paged_decode_attention`` — global page pool ``[P, bs, Hkv, hd]`` +
  per-lane block tables (``models/paged_kv.py``), the serving layout where
  sessions share prefix pages copy-on-write.  Ragged python block tables are
  padded through ``kernels.spec_verify.pad_block_tables`` (the same pow2
  bucketing as the batched NAV entries, pad id 0 = valid page, masked by
  ``lengths``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..spec_verify.ops import pad_block_tables
from .kernel import (
    decode_attention_pallas,
    paged_decode_attention_pallas,
    paged_decode_attention_q8_pallas,
)
from .ref import (
    decode_attention_ref,
    paged_decode_attention_q8_ref,
    paged_decode_attention_ref,
)


@functools.partial(jax.jit, static_argnames=("window", "impl", "block_k"))
def decode_attention(
    q: jax.Array,  # [B, H, hd]
    k_cache: jax.Array,  # [B, S, Hkv, hd]
    v_cache: jax.Array,
    lengths: jax.Array,  # [B]
    *,
    window: int = 1 << 30,
    impl: str = "interpret",
    block_k: int = 512,
) -> jax.Array:
    """Single-position decode attention over a flat contiguous KV cache."""
    H = q.shape[1]
    n_kv = k_cache.shape[2]
    if n_kv != H:
        k_cache = jnp.repeat(k_cache, H // n_kv, axis=2)
        v_cache = jnp.repeat(v_cache, H // n_kv, axis=2)
    if impl == "ref":
        return decode_attention_ref(q, k_cache, v_cache, lengths, window=window)
    return decode_attention_pallas(
        q, k_cache, v_cache, lengths, window=window, block_k=block_k, interpret=(impl == "interpret")
    )


@functools.partial(jax.jit, static_argnames=("window", "impl"))
def _paged_dispatch(q, k_pages, v_pages, block_tables, lengths, *, window, impl):
    H = q.shape[1]
    n_kv = k_pages.shape[2]
    if n_kv != H:
        k_pages = jnp.repeat(k_pages, H // n_kv, axis=2)
        v_pages = jnp.repeat(v_pages, H // n_kv, axis=2)
    if impl == "ref":
        return paged_decode_attention_ref(q, k_pages, v_pages, block_tables, lengths, window=window)
    return paged_decode_attention_pallas(
        q, k_pages, v_pages, block_tables, lengths, window=window, interpret=(impl == "interpret")
    )


@functools.partial(jax.jit, static_argnames=("window", "impl"))
def _paged_q8_dispatch(q, k_pages, v_pages, quant, block_tables, lengths, *, window, impl):
    H = q.shape[1]
    n_kv = k_pages.shape[2]
    if n_kv != H:
        k_pages = jnp.repeat(k_pages, H // n_kv, axis=2)
        v_pages = jnp.repeat(v_pages, H // n_kv, axis=2)
        quant = tuple(jnp.repeat(p, H // n_kv, axis=2) for p in quant)
    ks, kz, vs, vz = quant
    if impl == "ref":
        return paged_decode_attention_q8_ref(
            q, k_pages, v_pages, ks, kz, vs, vz, block_tables, lengths, window=window
        )
    return paged_decode_attention_q8_pallas(
        q, k_pages, v_pages, ks, kz, vs, vz, block_tables, lengths,
        window=window, interpret=(impl == "interpret"),
    )


def paged_decode_attention(
    q: jax.Array,  # [B, H, hd]
    k_pages: jax.Array,  # [P, bs, Hkv, hd]  (int8 payload when quantized)
    v_pages: jax.Array,
    block_tables,  # [B, G] int32 array, or B ragged python page-id lists
    lengths: jax.Array,  # [B]
    *,
    window: int = 1 << 30,
    impl: str = "interpret",
    bucket: bool = True,
    quant=None,  # (k_scale, k_zero, v_scale, v_zero), each [P, bs, Hkv] f32
    pad_page_id: int = 0,
) -> jax.Array:
    """Single-position decode attention gathered through KV block tables.

    ``block_tables`` may be a rectangular ``[B, G]`` int32 array (e.g. from
    ``PagedKVPool.table(sid, pad_to=G)``) or ragged per-lane page-id lists,
    which are padded here with the serving bucketing (``pad_block_tables``)
    using ``pad_page_id`` — pass the pool's ``sentinel_page`` so padded
    lanes never DMA another session's pages.  Bit-exact vs the flat entry on
    the same logical cache: ``impl='ref'`` by construction (page gather +
    flat oracle), Pallas impls by streaming pages in the flat kernel's
    block order (``tests/test_paged_attention.py``).

    With ``quant`` (the pool's four affine-parameter planes), pages are
    int8 and dequantized in-kernel; output error vs the fp32 cache is
    bounded per ``docs/kernels.md`` §7.
    """
    if isinstance(block_tables, (list, tuple)):
        block_tables = pad_block_tables(
            block_tables, batch_pad=len(block_tables), bucket=bucket, pad_id=pad_page_id
        )
    block_tables = jnp.asarray(block_tables, jnp.int32)
    lengths = jnp.asarray(lengths, jnp.int32)
    if quant is not None:
        return _paged_q8_dispatch(
            q, k_pages, v_pages, tuple(quant), block_tables, lengths,
            window=window, impl=impl,
        )
    return _paged_dispatch(
        q, k_pages, v_pages, block_tables, lengths, window=window, impl=impl
    )
