from . import ops, ref
from .kernel import decode_attention_pallas
from .ops import decode_attention
from .ref import decode_attention_ref

__all__ = ["decode_attention", "decode_attention_pallas", "decode_attention_ref", "ops", "ref"]
