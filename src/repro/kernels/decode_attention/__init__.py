from . import ops, ref
from .kernel import (
    decode_attention_pallas,
    paged_decode_attention_pallas,
    paged_decode_attention_q8_pallas,
)
from .ops import decode_attention, paged_decode_attention
from .ref import (
    decode_attention_ref,
    dequantize_pages,
    paged_decode_attention_q8_ref,
    paged_decode_attention_ref,
    paged_gather,
)

__all__ = [
    "decode_attention",
    "decode_attention_pallas",
    "decode_attention_ref",
    "dequantize_pages",
    "paged_decode_attention",
    "paged_decode_attention_pallas",
    "paged_decode_attention_q8_pallas",
    "paged_decode_attention_q8_ref",
    "paged_decode_attention_ref",
    "paged_gather",
    "ops",
    "ref",
]
