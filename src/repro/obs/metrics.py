"""Typed metric registry: Counter / Gauge / Histogram with labels + clock stamps.

The registry supersedes the ad-hoc ``Deque`` series scattered through
``EnvironmentMonitor`` and the list fields in ``RunStats`` with one typed
surface (their public fields keep working — the monitor *mirrors* its
observations into an attached registry, and ``RunStats.to_metrics`` exports
a finished run).  Every sample is stamped with the registry's injected
clock, so a run under ``VirtualClock`` produces bit-identical metric state
across reruns.

Prometheus exposition (:meth:`MetricRegistry.prometheus_text`) renders the
standard text format — ``# HELP``/``# TYPE`` headers, ``{label="v"}``
selectors, ``_bucket``/``_sum``/``_count`` histogram series — consumed by
the ``launch/serve.py --metrics-port`` endpoint (:mod:`repro.obs.endpoint`).
"""

from __future__ import annotations

from collections import deque
from typing import Any, Deque, Dict, List, Optional, Tuple

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricRegistry",
    "DEFAULT_BUCKETS",
    "LATENCY_BUCKETS",
]

#: Generic magnitude buckets (counts, bytes-ish scales).
DEFAULT_BUCKETS: Tuple[float, ...] = (1.0, 2.0, 5.0, 10.0, 20.0, 50.0, 100.0, 200.0, 500.0, 1000.0)

#: Latency buckets [s] sized for NAV round trips (ms → tens of seconds).
LATENCY_BUCKETS: Tuple[float, ...] = (
    0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0,
)

LabelKey = Tuple[Tuple[str, str], ...]


def _label_key(labels: Dict[str, Any]) -> LabelKey:
    """Canonical (sorted, stringified) label tuple used as the series key."""
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _render_labels(key: LabelKey) -> str:
    """Prometheus ``{a="1",b="x"}`` selector ('' when unlabeled)."""
    if not key:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in key)
    return "{" + inner + "}"


def _fmt(v: float) -> str:
    """Prometheus number formatting: integers without a trailing ``.0``."""
    f = float(v)
    return str(int(f)) if f.is_integer() else repr(f)


class _Metric:
    """Shared series bookkeeping: per-label values + clock-stamped samples."""

    kind = "untyped"

    def __init__(self, registry: "MetricRegistry", name: str, help: str):
        self.registry = registry
        self.name = name
        self.help = help
        self._series: Dict[LabelKey, float] = {}
        self._samples: Dict[LabelKey, Deque[Tuple[float, float]]] = {}

    def _record(self, key: LabelKey, value: float) -> None:
        self._series[key] = value
        dq = self._samples.get(key)
        if dq is None:
            dq = self._samples[key] = deque(maxlen=self.registry.sample_window)
        dq.append((self.registry.clock.monotonic(), value))

    def value(self, **labels: Any) -> float:
        """Current value of the series selected by ``labels`` (0.0 if unseen)."""
        return self._series.get(_label_key(labels), 0.0)

    def samples(self, **labels: Any) -> List[Tuple[float, float]]:
        """Clock-stamped (t, value) history for one series, oldest first."""
        return list(self._samples.get(_label_key(labels), ()))

    def series(self) -> Dict[LabelKey, float]:
        """Every labeled series' current value, keyed by canonical label tuple."""
        return dict(self._series)

    def expose(self) -> List[str]:
        """Prometheus text lines for this metric (sorted, deterministic)."""
        lines = [f"# HELP {self.name} {self.help}", f"# TYPE {self.name} {self.kind}"]
        for key in sorted(self._series):
            lines.append(f"{self.name}{_render_labels(key)} {_fmt(self._series[key])}")
        return lines


class Counter(_Metric):
    """Monotonically increasing count (`inc` rejects negative increments)."""

    kind = "counter"

    def inc(self, amount: float = 1.0, **labels: Any) -> None:
        """Add ``amount`` (≥ 0) to the labeled series."""
        if amount < 0:
            raise ValueError(f"counter {self.name} cannot decrease (inc {amount})")
        key = _label_key(labels)
        self._record(key, self._series.get(key, 0.0) + float(amount))


class Gauge(_Metric):
    """Point-in-time value that can move both ways."""

    kind = "gauge"

    def set(self, value: float, **labels: Any) -> None:
        """Set the labeled series to ``value``."""
        self._record(_label_key(labels), float(value))

    def inc(self, amount: float = 1.0, **labels: Any) -> None:
        """Adjust the labeled series by ``amount`` (may be negative)."""
        key = _label_key(labels)
        self._record(key, self._series.get(key, 0.0) + float(amount))


class Histogram(_Metric):
    """Cumulative histogram over fixed bucket edges (Prometheus semantics)."""

    kind = "histogram"

    def __init__(self, registry: "MetricRegistry", name: str, help: str,
                 buckets: Tuple[float, ...] = DEFAULT_BUCKETS):
        super().__init__(registry, name, help)
        edges = tuple(sorted(float(b) for b in buckets))
        if not edges:
            raise ValueError(f"histogram {name} needs at least one bucket edge")
        self.buckets = edges
        self._counts: Dict[LabelKey, List[int]] = {}
        self._sums: Dict[LabelKey, float] = {}
        self._totals: Dict[LabelKey, int] = {}

    def observe(self, value: float, **labels: Any) -> None:
        """Record one observation into the labeled series."""
        key = _label_key(labels)
        counts = self._counts.get(key)
        if counts is None:
            counts = self._counts[key] = [0] * len(self.buckets)
            self._sums[key] = 0.0
            self._totals[key] = 0
        v = float(value)
        for i, edge in enumerate(self.buckets):
            if v <= edge:
                counts[i] += 1
                break
        self._sums[key] += v
        self._totals[key] += 1
        self._record(key, v)  # `value()` reads the last observation

    def count(self, **labels: Any) -> int:
        """Total observations in the labeled series."""
        return self._totals.get(_label_key(labels), 0)

    def sum(self, **labels: Any) -> float:
        """Sum of observations in the labeled series."""
        return self._sums.get(_label_key(labels), 0.0)

    def bucket_counts(self, **labels: Any) -> Dict[float, int]:
        """Cumulative per-edge counts (``+inf`` implicit via ``count``)."""
        counts = self._counts.get(_label_key(labels), [0] * len(self.buckets))
        out, running = {}, 0
        for edge, c in zip(self.buckets, counts):
            running += c
            out[edge] = running
        return out

    def expose(self) -> List[str]:
        """Prometheus ``_bucket``/``_sum``/``_count`` series for every label set."""
        lines = [f"# HELP {self.name} {self.help}", f"# TYPE {self.name} {self.kind}"]
        for key in sorted(self._counts):
            cumulative = 0
            for edge, c in zip(self.buckets, self._counts[key]):
                cumulative += c
                le = _render_labels(key + (("le", _fmt(edge)),))
                lines.append(f"{self.name}_bucket{le} {cumulative}")
            inf = _render_labels(key + (("le", "+Inf"),))
            lines.append(f"{self.name}_bucket{inf} {self._totals[key]}")
            lines.append(f"{self.name}_sum{_render_labels(key)} {_fmt(self._sums[key])}")
            lines.append(f"{self.name}_count{_render_labels(key)} {self._totals[key]}")
        return lines


class MetricRegistry:
    """Name-keyed collection of typed metrics on one clock.

    ``counter``/``gauge``/``histogram`` are get-or-create (idempotent for a
    matching kind; a kind conflict raises), so instrumentation sites can
    resolve their metrics lazily without coordinating creation order.
    """

    def __init__(self, clock=None, sample_window: int = 256):
        if clock is None:
            # Lazy default: obs must not import the runtime at module load
            # (the runtime instruments itself with this package).
            from ..runtime.simclock import SYSTEM_CLOCK as clock  # type: ignore[no-redef]
        self.clock = clock
        self.sample_window = int(sample_window)
        self._metrics: Dict[str, _Metric] = {}

    def _get(self, cls, name: str, help: str, **kw) -> _Metric:
        existing = self._metrics.get(name)
        if existing is not None:
            if type(existing) is not cls:
                raise ValueError(
                    f"metric {name!r} already registered as {existing.kind}, not {cls.kind}"
                )
            return existing
        metric = cls(self, name, help, **kw)
        self._metrics[name] = metric
        return metric

    def counter(self, name: str, help: str = "") -> Counter:
        """Get or create a :class:`Counter`."""
        return self._get(Counter, name, help)  # type: ignore[return-value]

    def gauge(self, name: str, help: str = "") -> Gauge:
        """Get or create a :class:`Gauge`."""
        return self._get(Gauge, name, help)  # type: ignore[return-value]

    def histogram(self, name: str, help: str = "",
                  buckets: Tuple[float, ...] = DEFAULT_BUCKETS) -> Histogram:
        """Get or create a :class:`Histogram` with fixed ``buckets``."""
        return self._get(Histogram, name, help, buckets=buckets)  # type: ignore[return-value]

    def names(self) -> List[str]:
        """Registered metric names, sorted."""
        return sorted(self._metrics)

    def get(self, name: str) -> Optional[_Metric]:
        """The metric registered under ``name`` (None when absent)."""
        return self._metrics.get(name)

    def collect(self) -> Dict[str, Dict[str, float]]:
        """Deterministic nested snapshot: ``{name: {label_selector: value}}``."""
        out: Dict[str, Dict[str, float]] = {}
        for name in self.names():
            metric = self._metrics[name]
            out[name] = {
                _render_labels(key) or "{}": value
                for key, value in sorted(metric.series().items())
            }
        return out

    def prometheus_text(self) -> str:
        """Full Prometheus text exposition (sorted by metric name)."""
        lines: List[str] = []
        for name in self.names():
            lines.extend(self._metrics[name].expose())
        return "\n".join(lines) + "\n" if lines else ""


def absorb_monitor(monitor: Any, registry: MetricRegistry, prefix: str = "monitor") -> None:
    """Mirror an ``EnvironmentMonitor``'s current window into ``registry``.

    One-shot export of the monitor's sliding-window series (batch sizes,
    queue depths, KV residency, failover/recovery events) into typed
    metrics; attaching the registry to the monitor (``monitor.metrics``)
    instead streams them live at each observation.
    """
    hist = registry.histogram(f"{prefix}_verifier_batch", "Admitted NAV batch sizes")
    for b in monitor.verifier_batches():
        hist.observe(float(b))
    depth = registry.histogram(f"{prefix}_queue_depth", "Queue depth at admission")
    for d in monitor.verifier_depths():
        depth.observe(float(d))
    kv = registry.gauge(f"{prefix}_kv_resident_bytes", "Distinct resident KV bytes")
    for v in monitor.kv_bytes_series():
        kv.set(float(v))
    rec = registry.histogram(
        f"{prefix}_recovery_latency_s", "Offline-spell recovery latency", LATENCY_BUCKETS
    )
    for r in monitor.recovery_latencies():
        rec.observe(float(r))
    failovers = registry.counter(f"{prefix}_failovers", "NAV-timeout failovers")
    if monitor.failover_times():
        failovers.inc(len(monitor.failover_times()))
