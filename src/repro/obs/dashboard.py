"""Fleet dashboard: poll ``/snapshot`` and render a live terminal table.

Pure stdlib (``urllib`` + ANSI escapes), pointed at the
:class:`~repro.obs.endpoint.TelemetryEndpoint` that ``launch/serve.py
--metrics-port N`` starts next to a verifier or router::

    python -m repro.obs.dashboard 127.0.0.1:9100
    python -m repro.obs.dashboard 127.0.0.1:9100 --interval 0.5
    python -m repro.obs.dashboard 127.0.0.1:9100 --once   # one frame, no ANSI

Rendering (:func:`render_dashboard`) is a pure function of the polled JSON
payload, so the layout is unit-tested without a server; only the poll loop
touches the network, and it sleeps on an injectable clock.
"""

from __future__ import annotations

import json
import sys
import urllib.error
import urllib.request
from typing import Any, Dict, List, Optional, Sequence

__all__ = ["fetch_snapshot", "render_dashboard", "run_dashboard", "main"]

_CLEAR = "\x1b[2J\x1b[H"  # clear screen + home cursor

#: (header, payload key, format) for the per-verifier table columns.
_COLUMNS = (
    ("vid", "verifier", "d"),
    ("sess", "sessions_active", "d"),
    ("queue", "queue_depth", "d"),
    ("occ%", "occupancy", "pct"),
    ("nav", "nav_calls", "d"),
    ("tok/nav", None, "tok_per_nav"),
    ("acc%", None, "acc_rate"),
    ("kv_MB", None, "kv_mb"),
    ("kv_sess", "kv_resident_sessions", "d"),
    ("caphit", "kv_cap_hits", "d"),
)


def fetch_snapshot(host: str, port: int, timeout: float = 5.0) -> Dict[str, Any]:
    """GET ``/snapshot`` from a telemetry endpoint and parse the JSON."""
    url = f"http://{host}:{port}/snapshot"
    with urllib.request.urlopen(url, timeout=timeout) as resp:
        return json.loads(resp.read().decode())


def _cell(row: Dict[str, Any], key: Optional[str], fmt: str) -> str:
    if fmt == "d":
        return str(int(row.get(key, 0)))
    if fmt == "pct":
        return f"{100.0 * float(row.get(key, 0.0)):.1f}"
    if fmt == "tok_per_nav":
        nav = int(row.get("nav_calls", 0))
        if nav == 0:
            return "-"
        # Committed tokens per NAV: accepted drafts plus one correction each.
        return f"{(int(row.get('accepted_tokens', 0)) + nav) / nav:.2f}"
    if fmt == "acc_rate":
        verified = int(row.get("tokens_verified", 0))
        if verified == 0:
            return "-"
        return f"{100.0 * int(row.get('accepted_tokens', 0)) / verified:.1f}"
    if fmt == "kv_mb":
        return f"{int(row.get('kv_resident_bytes', 0)) / (1024 * 1024):.1f}"
    return "?"


def render_dashboard(payload: Dict[str, Any], ansi: bool = False) -> str:
    """Render one dashboard frame from a ``/snapshot`` payload.

    Header line (fleet aggregate + chaos counters), then one table row per
    verifier.  ``ansi`` prepends the clear-screen escape for live mode.
    """
    agg = payload.get("aggregate", {})
    verifiers: List[Dict[str, Any]] = payload.get("verifiers", [])
    extras = agg.get("extras", {})
    head = (
        f"PipeSD fleet @ t={float(agg.get('t', 0.0)):.3f}s  "
        f"verifiers={int(agg.get('n_verifiers', len(verifiers)))}  "
        f"sessions={int(agg.get('sessions_active', 0))}  "
        f"migrations={int(agg.get('migrations', 0))}  "
        f"failovers={int(agg.get('failovers', 0))}"
    )
    chaos_keys = [
        k
        for k in sorted(extras)
        if k.startswith("router_") or k in ("dropped_dead_sessions", "dropped_stragglers")
    ]
    chaos = "  ".join(f"{k}={int(extras[k])}" for k in chaos_keys if extras[k])

    rows = [[h for h, _, _ in _COLUMNS]]
    for v in sorted(verifiers, key=lambda r: int(r.get("verifier", 0))):
        rows.append([_cell(v, key, fmt) for _, key, fmt in _COLUMNS])
    if not verifiers and agg:
        rows.append([_cell(agg, key, fmt) for _, key, fmt in _COLUMNS])
    widths = [max(len(r[i]) for r in rows) for i in range(len(_COLUMNS))]
    table = [
        "  ".join(cell.rjust(w) for cell, w in zip(r, widths)) for r in rows
    ]
    table.insert(1, "-" * len(table[0]))

    lines = [head]
    if chaos:
        lines.append(chaos)
    lines.extend(table)
    frame = "\n".join(lines) + "\n"
    return (_CLEAR + frame) if ansi else frame


def run_dashboard(
    host: str,
    port: int,
    interval: float = 1.0,
    frames: Optional[int] = None,
    clock=None,
    out=None,
) -> int:
    """Poll-and-render loop; returns the number of frames drawn.

    ``frames=None`` runs until interrupted; ``frames=1`` is ``--once``.
    The sleep between polls comes from the injected clock, so tests drive
    the loop without wall-time waits.
    """
    if clock is None:
        from ..runtime.simclock import SYSTEM_CLOCK as clock  # type: ignore[no-redef]
    out = out or sys.stdout
    drawn = 0
    ansi = frames != 1
    while frames is None or drawn < frames:
        try:
            payload = fetch_snapshot(host, port)
        except (urllib.error.URLError, OSError) as e:
            out.write(f"telemetry endpoint {host}:{port} unreachable: {e}\n")
            out.flush()
            return drawn
        out.write(render_dashboard(payload, ansi=ansi))
        out.flush()
        drawn += 1
        if frames is None or drawn < frames:
            clock.sleep(interval)
    return drawn


def main(argv: Optional[Sequence[str]] = None) -> int:
    """CLI entry: ``python -m repro.obs.dashboard HOST:PORT [--interval S] [--once]``."""
    import argparse

    p = argparse.ArgumentParser(description="PipeSD fleet telemetry dashboard")
    p.add_argument("target", help="telemetry endpoint as HOST:PORT")
    p.add_argument("--interval", type=float, default=1.0, help="poll period [s]")
    p.add_argument("--once", action="store_true", help="draw one frame and exit")
    args = p.parse_args(argv)
    host, _, port_s = args.target.rpartition(":")
    if not host or not port_s.isdigit():
        p.error(f"target must be HOST:PORT, got {args.target!r}")
    try:
        drawn = run_dashboard(
            host, int(port_s), interval=args.interval, frames=1 if args.once else None
        )
    except KeyboardInterrupt:
        return 0
    return 0 if drawn else 1


if __name__ == "__main__":
    sys.exit(main())
