"""Deterministic observability subsystem (ROADMAP item 5).

Four layers, all driven by the injectable clock surface
(:mod:`repro.runtime.simclock`) so every recorded timestamp is
bit-reproducible under ``VirtualClock``:

* :mod:`repro.obs.trace` — span-based tracing with ring-buffer storage,
  Chrome trace-event JSON export (loadable in Perfetto) and a pure-Python
  per-round critical-path/overlap analyzer;
* :mod:`repro.obs.metrics` — a typed metric registry (Counter / Gauge /
  Histogram) with labels and clock-stamped samples;
* :mod:`repro.obs.endpoint` — ``TelemetryRequest``/``TelemetrySnapshot``
  builders riding the typed wire protocol, plus Prometheus-text and JSON
  HTTP exposition for the multi-process fleet;
* :mod:`repro.obs.dashboard` — a stdlib-only live terminal dashboard
  polling the endpoint.
"""

from .metrics import Counter, Gauge, Histogram, MetricRegistry
from .trace import NULL_TRACER, NullTracer, Span, Tracer, round_report, session_bubble_fractions

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricRegistry",
    "NULL_TRACER",
    "NullTracer",
    "Span",
    "Tracer",
    "round_report",
    "session_bubble_fractions",
]
