"""Span-based tracing on the injectable clock (deterministic under VirtualClock).

A :class:`Tracer` records named *spans* — ``draft``, ``upload``,
``nav_queue``, ``verify``, ``commit``, ``migrate``, ``frame`` — with
arbitrary scalar attributes (session, round, verifier, …) into a bounded
ring buffer.  Every timestamp comes from the tracer's clock, so a run under
``VirtualClock`` produces the *same* spans on every rerun: the exported
Chrome trace-event JSON is byte-identical across seeded reruns (asserted in
``tests/test_obs.py`` and the CI ``obs-smoke`` job).

The export (:meth:`Tracer.export_chrome_trace`) is the standard Chrome
``traceEvents`` format, loadable in ``chrome://tracing`` or Perfetto.  The
pure-Python analyzer (:func:`round_report` / :func:`session_bubble_fractions`)
reconstructs each (session, round)'s stage timeline and reports the pipeline
*bubble fraction* — the share of the round's wall span covered by no stage —
which is exactly the overlap PipeSD's pipelined drafting (§3.2/§4) exists to
shrink.

Instrumentation sites hold a tracer that defaults to the module-level
:data:`NULL_TRACER`, whose ``span`` context manager never reads the clock —
tracing disabled costs one attribute lookup and a no-op ``with``.
"""

from __future__ import annotations

import json
import threading
from collections import deque
from dataclasses import dataclass
from typing import Any, Deque, Dict, List, Optional, Tuple

__all__ = [
    "Span",
    "Tracer",
    "NullTracer",
    "NULL_TRACER",
    "STAGES",
    "round_report",
    "session_bubble_fractions",
]

#: Canonical stage names in pipeline order; the Chrome export maps each to a
#: fixed track (tid) so Perfetto lays rounds out consistently.
STAGES: Tuple[str, ...] = ("draft", "upload", "nav_queue", "verify", "commit", "migrate", "frame")

#: Stages that represent productive pipeline work for the bubble analyzer
#: (``migrate``/``frame`` are control-plane, not round stages).
ROUND_STAGES: Tuple[str, ...] = ("draft", "upload", "nav_queue", "verify", "commit")


def _default_clock():
    """The process-wide ``SYSTEM_CLOCK``, imported lazily.

    ``repro.runtime`` instruments itself with this package, so a module-level
    import here would be circular; resolving the default at first use keeps
    the dependency one-directional at import time.
    """
    from ..runtime.simclock import SYSTEM_CLOCK

    return SYSTEM_CLOCK


@dataclass(frozen=True)
class Span:
    """One finished span: half-open interval ``[t0, t1)`` plus attributes.

    ``attrs`` is a key-sorted tuple of (name, value) pairs so spans are
    hashable, comparable, and render deterministically.
    """

    name: str
    t0: float
    t1: float
    attrs: Tuple[Tuple[str, Any], ...] = ()

    @property
    def duration(self) -> float:
        """Span length [s] (never negative)."""
        return max(self.t1 - self.t0, 0.0)

    def get(self, key: str, default: Any = None) -> Any:
        """Attribute lookup by name."""
        for k, v in self.attrs:
            if k == key:
                return v
        return default


class _SpanContext:
    """Context manager produced by :meth:`Tracer.span`; records on exit."""

    __slots__ = ("_tracer", "_name", "_attrs", "_t0")

    def __init__(self, tracer: "Tracer", name: str, attrs: Dict[str, Any]):
        self._tracer = tracer
        self._name = name
        self._attrs = attrs
        self._t0 = 0.0

    def __enter__(self) -> "_SpanContext":
        self._t0 = self._tracer.clock.monotonic()
        return self

    def __exit__(self, *exc) -> bool:
        self._tracer.add(self._name, self._t0, self._tracer.clock.monotonic(), **self._attrs)
        return False


class Tracer:
    """Clock-driven span recorder with bounded ring-buffer storage.

    Thread-safe: spans may be recorded from any actor/thread; the ring
    buffer holds the most recent ``capacity`` finished spans.  Under
    ``VirtualClock`` the recording order is deterministic, so exports are
    byte-reproducible.
    """

    enabled = True

    def __init__(self, clock=None, capacity: int = 65536):
        self.clock = clock if clock is not None else _default_clock()
        self._spans: Deque[Span] = deque(maxlen=int(capacity))
        self._lock = threading.Lock()

    # -------------------------------------------------------------- record --
    def span(self, name: str, **attrs: Any) -> _SpanContext:
        """Context manager timing a stage: ``with tracer.span("draft", session=3):``."""
        return _SpanContext(self, name, attrs)

    def add(self, name: str, t0: float, t1: float, **attrs: Any) -> None:
        """Record an already-timed span (for queue waits measured from stamps)."""
        span = Span(name, float(t0), float(t1), tuple(sorted(attrs.items())))
        with self._lock:
            self._spans.append(span)

    # --------------------------------------------------------------- query --
    def spans(self) -> List[Span]:
        """Snapshot of the ring buffer, oldest first."""
        with self._lock:
            return list(self._spans)

    def clear(self) -> None:
        """Drop every recorded span."""
        with self._lock:
            self._spans.clear()

    def __len__(self) -> int:
        with self._lock:
            return len(self._spans)

    # -------------------------------------------------------------- export --
    def export_chrome_trace(self) -> str:
        """Chrome trace-event JSON (Perfetto-loadable), deterministically rendered.

        Events are complete (``ph="X"``) spans with microsecond timestamps;
        ``pid`` is the span's ``session`` attribute (0 when absent) and
        ``tid`` the stage's fixed track index, so one session renders as one
        process with a lane per stage.  Keys are sorted and floats rounded
        to the microsecond domain's 3 decimals — two identical runs produce
        byte-identical output.
        """
        events = []
        for s in self.spans():
            args = {k: v for k, v in s.attrs}
            tid = STAGES.index(s.name) if s.name in STAGES else len(STAGES)
            events.append(
                dict(
                    name=s.name,
                    ph="X",
                    ts=round(s.t0 * 1e6, 3),
                    dur=round(s.duration * 1e6, 3),
                    pid=int(args.pop("session", 0)),
                    tid=tid,
                    args=args,
                )
            )
        events.sort(key=lambda e: (e["ts"], e["pid"], e["tid"], e["name"]))
        return json.dumps(
            {"displayTimeUnit": "ms", "traceEvents": events},
            sort_keys=True,
            separators=(",", ":"),
        )


class _NullSpanContext:
    """Shared no-op context manager (never reads the clock)."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpanContext":
        return self

    def __exit__(self, *exc) -> bool:
        return False


_NULL_CTX = _NullSpanContext()


class NullTracer(Tracer):
    """Disabled tracer: ``span``/``add`` are no-ops with zero clock reads."""

    enabled = False

    def __init__(self):
        # No clock at all: the null tracer never reads one, and resolving
        # the default would import the runtime during its own import.
        self.clock = None
        self._spans = deque(maxlen=1)
        self._lock = threading.Lock()

    def span(self, name: str, **attrs: Any) -> _NullSpanContext:  # type: ignore[override]
        """A shared do-nothing context manager."""
        return _NULL_CTX

    def add(self, name: str, t0: float, t1: float, **attrs: Any) -> None:
        """Discard the span."""


#: Default tracer for every instrumentation site — tracing is opt-in.
NULL_TRACER = NullTracer()


# --------------------------------------------------------------------------- #
# Critical-path / overlap analysis
# --------------------------------------------------------------------------- #


def _union_length(intervals: List[Tuple[float, float]]) -> float:
    """Total length covered by a set of (possibly overlapping) intervals."""
    if not intervals:
        return 0.0
    intervals = sorted(intervals)
    total = 0.0
    cur_lo, cur_hi = intervals[0]
    for lo, hi in intervals[1:]:
        if lo > cur_hi:
            total += cur_hi - cur_lo
            cur_lo, cur_hi = lo, hi
        else:
            cur_hi = max(cur_hi, hi)
    total += cur_hi - cur_lo
    return total


def round_report(spans: List[Span]) -> List[Dict[str, Any]]:
    """Per-(session, round) stage timeline: wall, busy, bubble, critical stage.

    For every (session, round) key seen in ``ROUND_STAGES`` spans, reports:

    * ``wall`` — earliest stage start to latest stage end;
    * ``busy`` — interval-union time covered by *any* stage;
    * ``bubble_fraction`` — ``1 − busy/wall``: the share of the round during
      which the pipeline sat idle (the quantity early upload shrinks);
    * ``critical_stage`` — the stage with the largest total duration (ties
      break in pipeline order), i.e. the round's dominant latency term;
    * per-stage total durations under ``stage_s``.

    Spans missing a ``round`` attribute are ignored; sessions default to 0.
    """
    by_round: Dict[Tuple[int, int], List[Span]] = {}
    for s in spans:
        if s.name not in ROUND_STAGES:
            continue
        rnd = s.get("round")
        if rnd is None:
            continue
        key = (int(s.get("session", 0)), int(rnd))
        by_round.setdefault(key, []).append(s)

    reports: List[Dict[str, Any]] = []
    for (session, rnd) in sorted(by_round):
        group = by_round[(session, rnd)]
        t0 = min(s.t0 for s in group)
        t1 = max(s.t1 for s in group)
        wall = max(t1 - t0, 0.0)
        busy = _union_length([(s.t0, s.t1) for s in group if s.t1 > s.t0])
        stage_s = {name: 0.0 for name in ROUND_STAGES}
        for s in group:
            stage_s[s.name] += s.duration
        critical = max(ROUND_STAGES, key=lambda n: (stage_s[n], -ROUND_STAGES.index(n)))
        reports.append(
            dict(
                session=session,
                round=rnd,
                t0=t0,
                t1=t1,
                wall=wall,
                busy=min(busy, wall) if wall > 0 else busy,
                bubble_fraction=(1.0 - min(busy, wall) / wall) if wall > 0 else 0.0,
                critical_stage=critical,
                stage_s=stage_s,
            )
        )
    return reports


def session_bubble_fractions(spans: List[Span]) -> Dict[int, float]:
    """Per-session pipeline bubble fraction aggregated over its rounds.

    ``1 − Σ busy / Σ wall`` across the session's rounds — 0.0 means the
    stages tile the round perfectly (no idle gaps), higher means the
    pipeline is stalling between stages.
    """
    totals: Dict[int, Tuple[float, float]] = {}
    for rep in round_report(spans):
        wall, busy = totals.get(rep["session"], (0.0, 0.0))
        totals[rep["session"]] = (wall + rep["wall"], busy + rep["busy"])
    return {
        session: (1.0 - busy / wall) if wall > 0 else 0.0
        for session, (wall, busy) in sorted(totals.items())
    }


def critical_path(spans: List[Span], session: int, rnd: int) -> Optional[str]:
    """The dominant stage of one (session, round), or None when unrecorded."""
    for rep in round_report(spans):
        if rep["session"] == session and rep["round"] == rnd:
            return rep["critical_stage"]
    return None
