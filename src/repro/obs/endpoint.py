"""Live telemetry endpoint: protocol snapshots -> Prometheus text + JSON HTTP.

Two consumption paths share the same :class:`~repro.runtime.protocol.TelemetrySnapshot`
message:

* **in-band** — an edge client (or the fleet dashboard's poller) sends a
  ``TelemetryRequest`` up its existing link; ``CloudVerifier`` answers with
  its own snapshot, the ``Router`` answers with the fleet-wide aggregate
  (``verifier=-1``) built by :func:`aggregate_snapshots`;
* **out-of-band** — :class:`TelemetryEndpoint` serves ``/metrics``
  (Prometheus text exposition) and ``/snapshot`` (JSON) over plain HTTP for
  scrapers and the terminal dashboard (``launch/serve.py --metrics-port``).

The HTTP endpoint is wall-clock-only infrastructure, exactly like
``SocketTransport``: it refuses a ``VirtualClock`` (deterministic runs
interrogate the tracer/registry/snapshots directly instead of scraping).
"""

from __future__ import annotations

import json
from dataclasses import asdict
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any, Callable, Dict, Iterable, List, Optional, Sequence, Tuple, Union

from ..runtime.protocol import TelemetrySnapshot

__all__ = [
    "aggregate_snapshots",
    "snapshot_to_dict",
    "prometheus_text_from_snapshots",
    "TelemetryEndpoint",
    "SNAPSHOT_COUNTER_FIELDS",
    "SNAPSHOT_GAUGE_FIELDS",
]

#: Snapshot fields summed by :func:`aggregate_snapshots` and rendered as
#: Prometheus counters (monotone over a verifier's lifetime).
SNAPSHOT_COUNTER_FIELDS: Tuple[str, ...] = (
    "nav_calls",
    "tokens_verified",
    "accepted_tokens",
    "batched_calls",
    "kv_cap_hits",
    "migrations",
    "failovers",
)

#: Snapshot fields rendered as Prometheus gauges; summed in the aggregate
#: except ``occupancy`` (fleet mean — a fraction, not a volume).
SNAPSHOT_GAUGE_FIELDS: Tuple[str, ...] = (
    "sessions_active",
    "queue_depth",
    "occupancy",
    "verify_busy_time",
    "kv_used_blocks",
    "kv_free_blocks",
    "kv_resident_bytes",
    "kv_resident_sessions",
)

_INT_FIELDS = frozenset(
    f
    for f in SNAPSHOT_COUNTER_FIELDS + SNAPSHOT_GAUGE_FIELDS
    if f not in ("occupancy", "verify_busy_time")
)


def aggregate_snapshots(
    snaps: Sequence[TelemetrySnapshot],
    seq: int = 0,
    session: int = -1,
    t: Optional[float] = None,
    migrations: int = 0,
    failovers: int = 0,
    extras: Iterable[Tuple[str, float]] = (),
) -> TelemetrySnapshot:
    """Fold per-verifier snapshots into one fleet-wide ``verifier=-1`` snapshot.

    Counter and volume fields are summed, ``occupancy`` is the fleet mean,
    and ``t`` defaults to the newest member timestamp.  ``migrations`` /
    ``failovers`` override the summed fields when the caller (the router)
    owns those counters; ``extras`` lanes are summed across members by name,
    then the caller's own ``extras`` pairs are appended (caller names win).
    """
    fields: Dict[str, float] = {
        f: 0.0 for f in SNAPSHOT_COUNTER_FIELDS + SNAPSHOT_GAUGE_FIELDS
    }
    lane_sums: Dict[str, float] = {}
    t_max = 0.0
    for s in snaps:
        for f in fields:
            fields[f] += float(getattr(s, f))
        for name, value in zip(s.names, s.values):
            lane_sums[name] = lane_sums.get(name, 0.0) + value
        t_max = max(t_max, s.t)
    if snaps:
        fields["occupancy"] /= len(snaps)
    if migrations:
        fields["migrations"] = float(migrations)
    if failovers:
        fields["failovers"] = float(failovers)
    for name, value in extras:
        lane_sums[name] = float(value)
    lanes = sorted(lane_sums.items())
    kwargs: Dict[str, Any] = {
        f: int(v) if f in _INT_FIELDS else v for f, v in fields.items()
    }
    return TelemetrySnapshot(
        session=session,
        seq=seq,
        verifier=-1,
        n_verifiers=len(snaps),
        t=t if t is not None else t_max,
        names=tuple(n for n, _ in lanes),
        values=tuple(v for _, v in lanes),
        **kwargs,
    )


def snapshot_to_dict(snap: TelemetrySnapshot) -> Dict[str, Any]:
    """JSON-friendly dict: dataclass fields with extras lanes folded in.

    The parallel ``names``/``values`` tuples are replaced by an ``extras``
    mapping so consumers (the dashboard, ``/snapshot`` pollers) never see
    the wire layout.
    """
    d = asdict(snap)
    d.pop("names")
    d.pop("values")
    d["extras"] = snap.extras()
    return d


def _fmt(v: float) -> str:
    """Prometheus number formatting (integers without a trailing ``.0``)."""
    f = float(v)
    return str(int(f)) if f.is_integer() else repr(f)


def prometheus_text_from_snapshots(
    snaps: Sequence[TelemetrySnapshot],
    aggregate: Optional[TelemetrySnapshot] = None,
    prefix: str = "pipesd",
) -> str:
    """Render snapshots as Prometheus text, one series per verifier label.

    Per-field output is grouped under ``{prefix}_{field}`` with a
    ``verifier="<id>"`` label; the aggregate (when given) contributes the
    ``verifier="-1"`` series plus ``{prefix}_n_verifiers``.  Extras lanes
    render as ``{prefix}_extra_<name>``.  Output is sorted and
    deterministic for fixed inputs.
    """
    rows: List[TelemetrySnapshot] = list(snaps)
    if aggregate is not None:
        rows.append(aggregate)
    lines: List[str] = []
    for field in SNAPSHOT_COUNTER_FIELDS + SNAPSHOT_GAUGE_FIELDS:
        kind = "counter" if field in SNAPSHOT_COUNTER_FIELDS else "gauge"
        name = f"{prefix}_{field}"
        lines.append(f"# TYPE {name} {kind}")
        for s in sorted(rows, key=lambda s: s.verifier):
            lines.append(
                f'{name}{{verifier="{s.verifier}"}} {_fmt(float(getattr(s, field)))}'
            )
    extra_series: Dict[str, List[Tuple[int, float]]] = {}
    for s in rows:
        for lane, value in zip(s.names, s.values):
            extra_series.setdefault(lane, []).append((s.verifier, value))
    for lane in sorted(extra_series):
        name = f"{prefix}_extra_{lane}"
        lines.append(f"# TYPE {name} gauge")
        for vid, value in sorted(extra_series[lane]):
            lines.append(f'{name}{{verifier="{vid}"}} {_fmt(value)}')
    if aggregate is not None:
        lines.append(f"# TYPE {prefix}_n_verifiers gauge")
        lines.append(f"{prefix}_n_verifiers {aggregate.n_verifiers}")
    return "\n".join(lines) + "\n" if lines else ""


SnapshotSource = Callable[
    [],
    Union[
        TelemetrySnapshot,
        Sequence[TelemetrySnapshot],
        Tuple[Sequence[TelemetrySnapshot], TelemetrySnapshot],
    ],
]


class TelemetryEndpoint:
    """Minimal stdlib HTTP server exposing ``/metrics`` and ``/snapshot``.

    ``source`` is polled per request and may return one snapshot, a list of
    per-verifier snapshots, or a ``(snapshots, aggregate)`` pair — pass
    ``router.telemetry`` for a fleet, or a lambda over
    ``CloudVerifier.telemetry_snapshot`` for a single verifier.  An optional
    :class:`~repro.obs.metrics.MetricRegistry` contributes its exposition to
    ``/metrics`` below the snapshot series.

    Wall-clock only (scrapers live outside simulated time): constructing one
    under a ``VirtualClock`` raises, mirroring ``SocketTransport``.
    """

    def __init__(
        self,
        source: SnapshotSource,
        host: str = "127.0.0.1",
        port: int = 0,
        registry: Any = None,
        clock=None,
    ):
        if clock is None:
            from ..runtime.simclock import SYSTEM_CLOCK as clock  # type: ignore[no-redef]
        if getattr(clock, "virtual", False):
            raise ValueError(
                "TelemetryEndpoint runs on wall time; VirtualClock is not supported"
            )
        self.source = source
        self.registry = registry
        self.clock = clock
        endpoint = self

        class _Handler(BaseHTTPRequestHandler):
            def do_GET(self) -> None:  # noqa: N802 (stdlib handler API)
                try:
                    if self.path.split("?", 1)[0] == "/metrics":
                        body = endpoint.render_metrics().encode()
                        ctype = "text/plain; version=0.0.4; charset=utf-8"
                    elif self.path.split("?", 1)[0] == "/snapshot":
                        body = endpoint.render_snapshot_json().encode()
                        ctype = "application/json"
                    else:
                        self.send_error(404, "unknown path (try /metrics or /snapshot)")
                        return
                except Exception as e:  # pragma: no cover - surface, don't die
                    self.send_error(500, str(e))
                    return
                self.send_response(200)
                self.send_header("Content-Type", ctype)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def log_message(self, fmt: str, *args: Any) -> None:
                """Silence per-request stderr logging."""

        self._httpd = ThreadingHTTPServer((host, port), _Handler)
        self._httpd.daemon_threads = True
        self.host, self.port = self._httpd.server_address[:2]
        self._thread = clock.spawn(self._httpd.serve_forever, name="telemetry-http")

    # ------------------------------------------------------------- renders --
    def _resolve(self) -> Tuple[List[TelemetrySnapshot], Optional[TelemetrySnapshot]]:
        out = self.source()
        if isinstance(out, TelemetrySnapshot):
            return [out], None
        if (
            isinstance(out, tuple)
            and len(out) == 2
            and isinstance(out[1], TelemetrySnapshot)
        ):
            return list(out[0]), out[1]
        return list(out), None  # type: ignore[arg-type]

    def render_metrics(self) -> str:
        """The ``/metrics`` body: snapshot series + optional registry text."""
        snaps, agg = self._resolve()
        if agg is None and len(snaps) > 1:
            agg = aggregate_snapshots(snaps)
        text = prometheus_text_from_snapshots(snaps, agg)
        if self.registry is not None:
            text += self.registry.prometheus_text()
        return text

    def render_snapshot_json(self) -> str:
        """The ``/snapshot`` body: aggregate + per-verifier snapshot dicts."""
        snaps, agg = self._resolve()
        if agg is None:
            agg = aggregate_snapshots(snaps) if len(snaps) != 1 else snaps[0]
        payload = {
            "t": agg.t,
            "aggregate": snapshot_to_dict(agg),
            "verifiers": [snapshot_to_dict(s) for s in snaps],
        }
        return json.dumps(payload, sort_keys=True)

    # ----------------------------------------------------------- lifecycle --
    def close(self) -> None:
        """Shut the HTTP server down and release the port."""
        self._httpd.shutdown()
        self._httpd.server_close()
        self._thread.join(timeout=5.0)

    def __enter__(self) -> "TelemetryEndpoint":
        return self

    def __exit__(self, *exc: Any) -> bool:
        self.close()
        return False
