from .analyze import CellRoofline, analyze_cell, format_table, load_results, roofline_table
from . import hw

__all__ = ["CellRoofline", "analyze_cell", "format_table", "hw", "load_results", "roofline_table"]
