"""Roofline analysis over dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch × shape × mesh) cell, derives the three roofline terms from the
compiled dry-run artifact (all quantities are **per device**, matching
cost_analysis on the partitioned module):

    compute    = HLO_FLOPs_per_dev / peak_FLOP/s
    memory     = HLO_bytes_per_dev / HBM_bw
    collective = collective_bytes_per_dev / link_bw

plus MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) per device and the
useful-compute ratio MODEL_FLOPS / HLO_FLOPs.

Corrections applied (both reported):
* scan scaling — the dry-run already reports probe-scaled metrics
  (``flops_scaled`` etc.), see launch/dryrun.py;
* attention cond over-count — `lax.cond`-skipped attention blocks are counted
  by XLA's static cost analysis; the analyzer computes the statically-known
  executed-block fraction per layer pattern and reports a corrected compute
  term alongside the raw one.
"""

from __future__ import annotations

import json
import math
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, List, Optional

from repro.configs import SHAPES, get_config
from repro.models.config import GLOBAL_WINDOW

from .hw import HBM_BW, ICI_LINK_BW, PEAK_BF16_FLOPS

Q_CHUNK, K_CHUNK = 512, 1024  # must match models.layers


def attention_block_fraction(cfg, seq_len: int) -> float:
    """Statically-known fraction of (qi,ki) attention blocks that execute
    (causal + sliding-window skipping), averaged over the layer pattern."""
    bq, bk = min(Q_CHUNK, seq_len), min(K_CHUNK, seq_len)
    nq, nk = max(seq_len // bq, 1), max(seq_len // bk, 1)
    fracs = []
    for kind, window in zip(cfg.kinds, cfg.windows):
        if kind not in ("attn", "local"):
            continue
        needed = 0
        for qi in range(nq):
            for ki in range(nk):
                first_q, last_q = qi * bq, qi * bq + bq - 1
                first_k, last_k = ki * bk, ki * bk + bk - 1
                ok = (first_q - last_k) < (window if window else GLOBAL_WINDOW)
                ok = ok and (last_q - first_k >= 0)
                needed += ok
        fracs.append(needed / (nq * nk))
    return sum(fracs) / len(fracs) if fracs else 1.0


def model_flops_per_device(arch: str, shape_name: str, n_devices: int) -> float:
    """6·N·D (train) / 2·N·D (forward-only serve ops), active params for MoE."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens / n_devices
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens / n_devices
    # decode: one token per lane (the KV read is the memory term, not FLOPs).
    tokens = shape.global_batch * 1
    return 2.0 * n * tokens / n_devices


@dataclass
class CellRoofline:
    arch: str
    shape: str
    mesh: str
    compute_s: float
    compute_corrected_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    hlo_flops: float
    useful_ratio: float
    per_device_gib: float
    fits: bool
    note: str = ""

    def bound_time(self) -> float:
        return max(self.compute_corrected_s, self.memory_s, self.collective_s)

    def roofline_fraction(self) -> float:
        """Useful-compute roofline fraction: time the chip *should* spend on
        MODEL_FLOPS at peak vs the bound term."""
        ideal = self.model_flops / PEAK_BF16_FLOPS
        return ideal / max(self.bound_time(), 1e-30)


def analyze_cell(rec: dict) -> Optional[CellRoofline]:
    if not rec.get("ok") or rec.get("skipped"):
        return None
    arch, shape_name, mesh = rec["arch"], rec["shape"], rec["mesh"]
    n_dev = rec["devices"]
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    flops = rec.get("flops_scaled", rec["flops"])
    bytes_acc = rec.get("bytes_scaled", rec["bytes_accessed"])
    coll = rec.get("collective_bytes_scaled", rec["collective_bytes"])
    compute = flops / PEAK_BF16_FLOPS
    memory = bytes_acc / HBM_BW
    collective = coll / ICI_LINK_BW
    # Attention cond correction: scale the attention share of FLOPs by the
    # executed-block fraction.  Approximation: attention FLOPs fraction from
    # the analytic ratio attn/(attn+matmul) per token.
    frac_exec = attention_block_fraction(cfg, shape.seq_len if shape.kind != "decode" else 1)
    # attention share ≈ 2·S_eff·d_attn / (params/L per-layer matmul flops)
    attn_flops_tok = 4.0 * shape.seq_len * cfg.q_dim if shape.kind != "decode" else 0.0
    layer_params = max(cfg.active_param_count() - cfg.padded_vocab_size * cfg.d_model, 1) / max(cfg.n_layers, 1)
    mat_flops_tok = 2.0 * layer_params
    attn_share = attn_flops_tok / (attn_flops_tok + mat_flops_tok)
    corrected = compute * (1.0 - attn_share * (1.0 - frac_exec))
    model_fl = model_flops_per_device(arch, shape_name, n_dev)
    terms = {"compute": corrected, "memory": memory, "collective": collective}
    dominant = max(terms, key=terms.get)
    note = ""
    if cfg.family == "ssm":
        note = "sLSTM time-scan flops under-counted (rolled scan; see dryrun docs)"
    return CellRoofline(
        arch=arch, shape=shape_name, mesh=mesh,
        compute_s=compute, compute_corrected_s=corrected,
        memory_s=memory, collective_s=collective, dominant=dominant,
        model_flops=model_fl, hlo_flops=flops,
        useful_ratio=model_fl / max(flops, 1e-30),
        per_device_gib=rec["per_device_bytes"] / 2**30,
        fits=rec["fits_v5e_16g"],
        note=note,
    )


def load_results(results_dir: Path) -> List[dict]:
    return [json.loads(p.read_text()) for p in sorted(Path(results_dir).glob("*.json"))]


def roofline_table(results_dir: Path, mesh: str = "pod") -> List[CellRoofline]:
    cells = []
    for rec in load_results(results_dir):
        if rec.get("mesh") != mesh:
            continue
        c = analyze_cell(rec)
        if c is not None:
            cells.append(c)
    return cells


def format_table(cells: List[CellRoofline]) -> str:
    hdr = (
        f"{'arch':22s} {'shape':12s} {'compute*':>10s} {'memory':>10s} {'collect.':>10s} "
        f"{'bound':>10s} {'RL-frac':>8s} {'useful':>7s} {'GiB/dev':>8s} fits"
    )
    out = [hdr, "-" * len(hdr)]
    for c in sorted(cells, key=lambda c: (c.arch, c.shape)):
        out.append(
            f"{c.arch:22s} {c.shape:12s} {c.compute_corrected_s*1e3:9.2f}ms {c.memory_s*1e3:9.2f}ms "
            f"{c.collective_s*1e3:9.2f}ms {c.dominant:>10s} {c.roofline_fraction():7.1%} "
            f"{c.useful_ratio:6.2f} {c.per_device_gib:8.2f} {'Y' if c.fits else 'N'}"
        )
    return "\n".join(out)
