"""TPU v5e hardware constants (per chip) for the roofline model."""

PEAK_BF16_FLOPS = 197e12  # 197 TFLOP/s bf16
HBM_BW = 819e9  # 819 GB/s
ICI_LINK_BW = 50e9  # ~50 GB/s per link
HBM_BYTES = 16 * 1024**3  # 16 GiB
