#!/usr/bin/env python3
"""Diff a regenerated BENCH_<area>.json against the committed copy.

Committed bench files are the contract: deterministic benches (virtual
clock, pool accounting, roofline traffic models) must reproduce them on
any host.  This tool compares field-by-field with two tolerance bands:

* **exact** — integers, strings, counts, byte totals, ratios: any drift
  is a regression (or an intentional change that must be committed);
* **timing band (±5%)** — fields whose name marks them as time-like or
  rate-like (``*_ms``, ``*_us``, ``*_s``, ``tokens_per_s``, ``speedup``):
  compared with 5% relative tolerance so a legitimately re-derived model
  constant or quantile doesn't hard-fail, while real regressions do;
* **informational (skipped)** — fields prefixed ``host_`` measure host
  wall time (e.g. codec ns/message): committed for the record, never
  compared — they vary with the machine, not the code.

Rows are matched by their identity key (``name`` when present, else the
sorted non-float fields), so row order never matters.

    python tools/bench_diff.py BENCH_kernels.json regen/BENCH_kernels.json
    python tools/bench_diff.py --area fleet   # regenerate in-process + diff

Exit code 1 on any mismatch, listing every offending field.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

TIMING_SUFFIXES = ("_ms", "_us", "_ns", "_s")
TIMING_FIELDS = {"tokens_per_s", "speedup", "speedup_vs_composed", "speedup_vs_1shard", "bw_frac"}
TIMING_RTOL = 0.05
HOST_PREFIX = "host_"  # informational wall-time fields: never compared

REGEN = {
    "fleet": ("benchmarks.fleet_bench", "fleet_committed"),
    "kernels": ("benchmarks.kernel_bench", "kernels"),
    "scenarios": ("benchmarks.scenario_bench", "scenarios"),
}


def is_timing_field(name: str) -> bool:
    return name in TIMING_FIELDS or name.endswith(TIMING_SUFFIXES)


def row_key(row: dict) -> str:
    if "name" in row:
        return str(row["name"])
    ident = {k: v for k, v in sorted(row.items()) if not isinstance(v, float)}
    return json.dumps(ident, sort_keys=True)


def diff_rows(committed: list, regen: list) -> list:
    """Returns a list of human-readable mismatch strings (empty == match)."""
    errors = []
    a = {row_key(r): r for r in committed}
    b = {row_key(r): r for r in regen}
    for key in sorted(set(a) | set(b)):
        if key not in a:
            errors.append(f"row only in regenerated output: {key}")
            continue
        if key not in b:
            errors.append(f"row only in committed file: {key}")
            continue
        ra, rb = a[key], b[key]
        for field in sorted(set(ra) | set(rb)):
            if field.startswith(HOST_PREFIX):
                continue
            va, vb = ra.get(field), rb.get(field)
            if va == vb:
                continue
            if (
                is_timing_field(field)
                and isinstance(va, (int, float))
                and isinstance(vb, (int, float))
                and va
                and abs(vb - va) / abs(va) <= TIMING_RTOL
            ):
                continue
            band = f"±{TIMING_RTOL:.0%}" if is_timing_field(field) else "exact"
            errors.append(f"{key}.{field}: committed={va!r} regenerated={vb!r} [{band}]")
    return errors


def _regenerate(area: str) -> list:
    import importlib

    mod_name, fn_name = REGEN[area]
    sys.path.insert(0, str(Path(__file__).resolve().parent.parent))
    rows, _lines = getattr(importlib.import_module(mod_name), fn_name)()
    from benchmarks.common import round_metrics

    return round_metrics(rows)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("committed", nargs="?", help="committed BENCH_<area>.json")
    ap.add_argument("regenerated", nargs="?", help="freshly generated copy")
    ap.add_argument("--area", choices=sorted(REGEN), help="regenerate in-process and diff")
    args = ap.parse_args(argv)

    if args.area:
        committed_path = Path(__file__).resolve().parent.parent / f"BENCH_{args.area}.json"
        committed = json.loads(committed_path.read_text())["rows"]
        regen = _regenerate(args.area)
        label = f"BENCH_{args.area}.json"
    elif args.committed and args.regenerated:
        committed = json.loads(Path(args.committed).read_text())["rows"]
        regen = json.loads(Path(args.regenerated).read_text())["rows"]
        label = args.committed
    else:
        ap.error("pass two files, or --area to regenerate in-process")
        return 2

    errors = diff_rows(committed, regen)
    if errors:
        print(f"{label}: {len(errors)} mismatch(es)")
        for e in errors:
            print(f"  {e}")
        return 1
    print(f"{label}: {len(committed)} rows match (exact + {TIMING_RTOL:.0%} timing band)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
