#!/usr/bin/env python3
"""Markdown link checker (stdlib only) for README/docs CI.

Checks every ``[text](target)`` and bare-reference link in the given
markdown files:

* relative file targets must exist on disk (resolved against the file's
  directory, ``#fragment`` suffixes stripped);
* intra-document ``#fragment`` links must match a heading slug in the file;
* ``http(s)://`` / ``mailto:`` targets are reported but not fetched (CI must
  stay hermetic).

Exit code 1 when any relative link is broken.

    python tools/check_links.py README.md docs/*.md
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
CODE_FENCE_RE = re.compile(r"```.*?```", re.DOTALL)


def heading_slugs(text: str) -> set:
    """GitHub-style anchor slugs for every heading in the document."""
    slugs = set()
    for h in HEADING_RE.findall(CODE_FENCE_RE.sub("", text)):
        h = re.sub(r"[`*_]", "", h.strip().lower())
        h = re.sub(r"[^\w\- ]", "", h)
        slugs.add(re.sub(r"\s+", "-", h).strip("-"))
    return slugs


def check_file(path: Path) -> list:
    """Return a list of broken-link descriptions for one markdown file."""
    text = path.read_text(encoding="utf-8")
    broken = []
    for target in LINK_RE.findall(CODE_FENCE_RE.sub("", text)):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        if target.startswith("#"):
            if target[1:].lower() not in heading_slugs(text):
                broken.append(f"{path}: missing anchor {target}")
            continue
        rel, _, frag = target.partition("#")
        dest = (path.parent / rel).resolve()
        if not dest.exists():
            broken.append(f"{path}: missing file {target}")
        elif frag and dest.suffix == ".md":
            if frag.lower() not in heading_slugs(dest.read_text(encoding="utf-8")):
                broken.append(f"{path}: missing anchor #{frag} in {rel}")
    return broken


def main(argv: list) -> int:
    """Check every file given on the command line; print a summary."""
    if not argv:
        print("usage: check_links.py FILE.md [FILE.md ...]", file=sys.stderr)
        return 2
    broken = []
    n_files = 0
    for arg in argv:
        p = Path(arg)
        if not p.exists():
            broken.append(f"{p}: file not found")
            continue
        n_files += 1
        broken.extend(check_file(p))
    for b in broken:
        print(f"BROKEN  {b}")
    print(f"checked {n_files} files: {'FAIL' if broken else 'ok'} ({len(broken)} broken)")
    return 1 if broken else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
