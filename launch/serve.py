"""Two-process cloud-edge serving over the socket transport.

This is the paper's testbed shape (edge client and cloud verifier as
separate machines talking over the network) on the repo's typed wire
protocol: the cloud process runs ``CloudVerifier`` behind a
``SocketListener``, the edge process dials it with ``connect_transport``
(``Hello``/``Attach`` version handshake) and streams tokens through
``EdgeClient`` over length-prefixed protocol frames.

Run the two roles in two shells (or two machines)::

    PYTHONPATH=src python launch/serve.py --listen 127.0.0.1:7421 --sessions 1
    PYTHONPATH=src python launch/serve.py --connect 127.0.0.1:7421 --tokens 64

With the default deterministic oracle draft/backend pair, the edge
process's committed stream equals the oracle stream exactly — compare
with::

    PYTHONPATH=src python launch/serve.py --print-oracle 64

(``--check-oracle`` makes the client do that diff itself and exit
non-zero on any mismatch.)  ``--demo`` runs both roles over a loopback
socket in one process.

``--router`` runs the multi-verifier control plane in front of a fleet:
clients dial the router exactly as they would a lone verifier; sessions
are placed least-loaded and can live-migrate between fleet members
mid-stream.  The fleet is either in-process (``--verifiers N``) or
remote verifier processes (repeatable ``--verifier HOST:PORT``)::

    PYTHONPATH=src python launch/serve.py --listen 127.0.0.1:7431 --sessions 0
    PYTHONPATH=src python launch/serve.py --listen 127.0.0.1:7432 --sessions 0
    PYTHONPATH=src python launch/serve.py --router 127.0.0.1:7421 \\
        --verifier 127.0.0.1:7431 --verifier 127.0.0.1:7432 --migrate-every 0.3
    PYTHONPATH=src python launch/serve.py --connect 127.0.0.1:7421 \\
        --tokens 64 --check-oracle

``--migrate-every S`` forces a round-robin migration sweep every S
seconds — the committed stream must stay oracle-exact through every
hand-off (this is the CI router-smoke job).

``--metrics-port N`` (cloud or router role) starts the live telemetry
endpoint next to the listener — Prometheus text at ``/metrics``, JSON at
``/snapshot`` — announced as ``METRICS host:port`` (0 = ephemeral).  The
terminal fleet dashboard polls it::

    PYTHONPATH=src python launch/serve.py --router 127.0.0.1:7421 \\
        --verifiers 2 --metrics-port 9100
    PYTHONPATH=src python launch/serve.py --dashboard 127.0.0.1:9100
    python -m repro.obs.dashboard 127.0.0.1:9100        # equivalent

``--backend spec --shards N`` swaps in the real fused NAV verifier with
its target forward sharded across an N-device mesh
(``ShardedSpecVerifyBackend``): paged KV pages partitioned on the head
axis, one ``shard_map`` launch per dispatch.  On a CPU-only host the
process forces ``--xla_force_host_platform_device_count=N`` so the mesh
exists; the wire protocol and every client stay oblivious to N::

    PYTHONPATH=src python launch/serve.py --listen 127.0.0.1:7421 \\
        --backend spec --shards 4 --sessions 1
"""

from __future__ import annotations

import argparse
import os
import sys
from pathlib import Path
from typing import Tuple

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.runtime import (  # noqa: E402 (path bootstrap above)
    SYSTEM_CLOCK,
    ChannelConfig,
    CloudVerifier,
    Detach,
    EdgeClient,
    EdgeConfig,
    LocalVerifier,
    OracleBackend,
    OracleDraft,
    OracleStream,
    RemoteVerifier,
    Router,
    SocketListener,
    SyntheticBackend,
    SyntheticDraft,
    connect_transport,
)


def _host_port(spec: str) -> Tuple[str, int]:
    host, _, port = spec.rpartition(":")
    if not host or not port.isdigit():
        raise argparse.ArgumentTypeError(f"expected HOST:PORT, got {spec!r}")
    return host, int(port)


def _start_metrics_endpoint(args, source):
    """Start a ``TelemetryEndpoint`` when ``--metrics-port`` asks for one.

    Announced as ``METRICS host:port`` right after the listener's own
    ``LISTENING`` line so harnesses can scrape the ephemeral port.
    """
    if args.metrics_port is None:
        return None
    from repro.obs.endpoint import TelemetryEndpoint

    ep = TelemetryEndpoint(source, host="127.0.0.1", port=args.metrics_port)
    print(f"METRICS {ep.host}:{ep.port}", flush=True)
    return ep


def run_server(args) -> int:
    """Cloud role: listen, attach socket sessions, serve until they finish."""
    host, port = args.listen
    backend, cv_kwargs = _make_backend(args)
    verifier = CloudVerifier(backend, batch_window=args.batch_window, **cv_kwargs)
    listener = SocketListener(
        lambda sid, transport: verifier.attach(sid, transport, transport),
        host=host,
        port=port,
    )
    verifier.start()
    # Port 0 binds ephemerally; announce the real port for the client side.
    print(f"LISTENING {listener.host}:{listener.port}", flush=True)
    endpoint = _start_metrics_endpoint(args, verifier.telemetry_snapshot)
    try:
        while True:
            SYSTEM_CLOCK.sleep(0.1)
            done = sum(t.closed for t in listener.transports)
            if args.sessions and done >= args.sessions:
                break
    except KeyboardInterrupt:
        pass
    finally:
        if endpoint is not None:
            endpoint.close()
        listener.close()
        verifier.stop()
    s = verifier.stats
    print(
        f"SERVED sessions={listener.stats['accepted']} nav_calls={s['nav_calls']}"
        f" tokens_verified={s['tokens_verified']} batched_calls={s['batched_calls']}",
        flush=True,
    )
    return 0


def _make_backend(args):
    """Build ``(backend, extra CloudVerifier kwargs)`` for the chosen mode."""
    if args.backend == "spec":
        return _spec_backend(args)
    if args.backend == "oracle":
        backend = OracleBackend(
            seed=args.seed, verify_time=args.verify_time, verify_time_per_token=0.0
        )
        return backend, {}
    return SyntheticBackend(seed=args.seed, verify_time=args.verify_time), {}


def _spec_backend(args):
    """The real fused NAV verifier, sharded over ``--shards`` devices.

    A tensor-mode paged KV pool (partitioned per shard on the head axis) and
    a seeded deterministic target (queries + LM head) drive
    ``ShardedSpecVerifyBackend`` — one sharded ``shard_map`` launch per
    dispatch, with the dispatcher (and the wire protocol) oblivious to the
    shard count.  ``--shards 1`` degenerates to a single-device mesh and is
    bit-identical to the unsharded ``SpecVerifyBackend``.
    """
    import jax
    import numpy as np

    from repro.models.paged_kv import PagedKVPool
    from repro.runtime import ShardedSpecVerifyBackend

    H, hd, bs, V = 2, 8, 4, 256
    pool = PagedKVPool(
        num_blocks=256, block_size=bs, n_layers=1, n_kv_heads=H, head_dim=hd,
        quantize="int8" if args.kv_quant == "int8" else None,
    )
    key = jax.random.PRNGKey(args.seed)
    w = np.asarray(jax.random.normal(jax.random.fold_in(key, 77), (H * hd, V)) * 4, np.float32)

    def query_fn(session, tokens):
        k = jax.random.fold_in(jax.random.fold_in(key, 88), session * 131 + len(tokens))
        return np.asarray(jax.random.normal(k, (len(tokens) + 1, H, hd)), np.float32)

    backend = ShardedSpecVerifyBackend(
        shards=args.shards, kv_pool=pool, query_fn=query_fn, lm_head=w,
        impl="ref", block_v=256,
    )
    return backend, {"kv_pool": pool}


def run_router(args) -> int:
    """Control-plane role: route socket clients across a verifier fleet."""
    host, port = args.router
    fleet = []
    for vhost, vport in args.verifier or ():
        fleet.append(
            RemoteVerifier(
                len(fleet), vhost, vport, cfg=ChannelConfig(alpha=0.001, beta=0.0001)
            )
        )
    for _ in range(args.verifiers):
        backend, cv_kwargs = _make_backend(args)
        v = CloudVerifier(backend, batch_window=args.batch_window, **cv_kwargs)
        v.start()
        fleet.append(LocalVerifier(len(fleet), v))
    if not fleet:
        print("--router needs --verifier HOST:PORT and/or --verifiers N", file=sys.stderr)
        return 2
    router = Router(fleet, rebalance_interval=args.migrate_every)
    # FleetFullError propagates into the listener, which hangs up on the
    # refused client; everyone already placed keeps streaming.
    listener = SocketListener(
        lambda sid, t: router.attach(sid, t, t), host=host, port=port
    )
    router.start()
    print(f"LISTENING {listener.host}:{listener.port}", flush=True)
    endpoint = _start_metrics_endpoint(args, router.telemetry)
    try:
        while True:
            SYSTEM_CLOCK.sleep(0.1)
            done = sum(1 for rs in list(router.sessions.values()) if rs.done)
            if args.sessions and done >= args.sessions:
                break
    except KeyboardInterrupt:
        pass
    finally:
        if endpoint is not None:
            endpoint.close()
        listener.close()
        router.stop()
        for vc in fleet:
            vc.stop()
    s = router.stats
    print(
        f"ROUTED sessions={s['sessions_placed']} migrations={s['migrations']}"
        f" failover_migrations={s['failover_migrations']} drains={s['drains']}"
        f" crashes={s['verifier_crashes']} refusals={s['admission_refusals']}",
        flush=True,
    )
    return 0


def run_client(args) -> int:
    """Edge role: dial the cloud, stream ``--tokens`` tokens, print them."""
    host, port = args.connect
    transport = connect_transport(
        host, port, session=args.session, cfg=ChannelConfig(alpha=0.001, beta=0.0001)
    )
    if args.draft == "oracle":
        draft = OracleDraft(seed=args.seed)
    else:
        draft = SyntheticDraft(seed=args.seed)
    cfg = EdgeConfig(gamma=args.gamma, window=8, nav_timeout=args.nav_timeout)
    client = EdgeClient(transport.session, transport, transport, cfg, draft=draft)
    stats = client.run(args.tokens)
    client.seq += 1
    transport.send(Detach(session=transport.session, seq=client.seq))
    transport.close()
    stream = client.tokens[: args.tokens]
    for tok in stream:
        print(tok)
    print(
        f"# session={transport.session} rounds={stats['rounds']}"
        f" accepted={stats['accepted_tokens']} failovers={stats['failovers']}"
        f" wall={stats['wall_time']:.2f}s",
        file=sys.stderr,
    )
    if args.check_oracle:
        expect = OracleStream(args.seed).prefix(len(stream))
        if stream != expect:
            print("# ORACLE MISMATCH", file=sys.stderr)
            return 1
        print("# stream == oracle: OK", file=sys.stderr)
    return 0


def run_demo(args) -> int:
    """Both roles over a loopback socket in one process (quickstart)."""
    backend = OracleBackend(seed=args.seed, verify_time=args.verify_time, verify_time_per_token=0.0)
    verifier = CloudVerifier(backend, batch_window=args.batch_window)
    listener = SocketListener(
        lambda sid, t: verifier.attach(sid, t, t), host="127.0.0.1", port=0
    )
    verifier.start()
    args.connect = (listener.host, listener.port)
    args.check_oracle = True
    try:
        return run_client(args)
    finally:
        listener.close()
        verifier.stop()


def main(argv=None) -> int:
    """CLI entry: ``--listen`` (cloud), ``--connect`` (edge), or helpers."""
    p = argparse.ArgumentParser(description=__doc__, formatter_class=argparse.RawDescriptionHelpFormatter)
    role = p.add_mutually_exclusive_group(required=True)
    role.add_argument("--listen", type=_host_port, metavar="HOST:PORT", help="run the cloud verifier")
    role.add_argument("--connect", type=_host_port, metavar="HOST:PORT", help="run the edge client")
    role.add_argument("--router", type=_host_port, metavar="HOST:PORT", help="run the fleet router")
    role.add_argument("--demo", action="store_true", help="loopback demo: both roles, one process")
    role.add_argument(
        "--print-oracle", type=int, metavar="N", help="print the first N oracle tokens and exit"
    )
    role.add_argument(
        "--dashboard", type=_host_port, metavar="HOST:PORT",
        help="render the live fleet dashboard from a --metrics-port endpoint",
    )
    p.add_argument("--seed", type=int, default=7, help="oracle/synthetic seed (must match across roles)")
    p.add_argument("--backend", choices=("oracle", "synthetic", "spec"), default="oracle")
    p.add_argument(
        "--shards", type=int, default=1,
        help="spec backend: shard the target verify over N mesh devices",
    )
    p.add_argument(
        "--kv-quant", choices=("none", "int8"), default="none",
        help="spec backend: paged-KV page storage (int8 = quantized pages)",
    )
    p.add_argument("--draft", choices=("oracle", "synthetic"), default="oracle")
    p.add_argument("--sessions", type=int, default=1, help="server exits after N sessions finish (0 = forever)")
    p.add_argument("--session", type=int, default=0, help="client's proposed session id")
    p.add_argument("--tokens", type=int, default=64, help="tokens to stream per client")
    p.add_argument(
        "--check-oracle", action="store_true",
        help="client: verify the committed stream equals the oracle stream (exit 1 on mismatch)",
    )
    p.add_argument(
        "--verifier", type=_host_port, action="append", metavar="HOST:PORT",
        help="router: add a remote fleet member (repeatable)",
    )
    p.add_argument(
        "--verifiers", type=int, default=0,
        help="router: number of in-process fleet members to spawn",
    )
    p.add_argument(
        "--migrate-every", type=float, default=None, metavar="S",
        help="router: force a round-robin migration sweep every S seconds",
    )
    p.add_argument(
        "--metrics-port", type=int, default=None, metavar="N",
        help="server/router: HTTP telemetry endpoint port (0 = ephemeral, "
        "announced as 'METRICS host:port'); serves /metrics and /snapshot",
    )
    p.add_argument(
        "--once", action="store_true",
        help="dashboard: draw one frame and exit (no ANSI clear)",
    )
    p.add_argument(
        "--interval", type=float, default=1.0, metavar="S",
        help="dashboard: poll period [s]",
    )
    p.add_argument("--gamma", type=float, default=0.005, help="edge per-token draft time [s]")
    p.add_argument("--nav-timeout", type=float, default=5.0, help="edge NAV timeout before failover [s]")
    p.add_argument("--batch-window", type=float, default=0.002, help="server NAV coalescing window [s]")
    p.add_argument("--verify-time", type=float, default=0.002, help="simulated target forward time [s]")
    args = p.parse_args(argv)
    if args.backend == "spec" and args.shards > 1:
        # The host mesh needs N visible devices BEFORE jax initializes its
        # backends (first jax.devices() call) — force the CPU device count
        # here so `--shards N` works on a plain CPU host.
        flags = os.environ.get("XLA_FLAGS", "")
        if "xla_force_host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                f"{flags} --xla_force_host_platform_device_count={args.shards}".strip()
            )
    if args.print_oracle is not None:
        for tok in OracleStream(args.seed).prefix(args.print_oracle):
            print(tok)
        return 0
    if args.dashboard:
        from repro.obs.dashboard import run_dashboard

        host, port = args.dashboard
        drawn = run_dashboard(
            host, port, interval=args.interval, frames=1 if args.once else None
        )
        return 0 if drawn else 1
    if args.demo:
        return run_demo(args)
    if args.listen:
        return run_server(args)
    if args.router:
        return run_router(args)
    return run_client(args)


if __name__ == "__main__":
    raise SystemExit(main())
